//! Virtual-clock event scheduler: latency, stragglers and phase timeouts
//! as a deterministic, replayable simulation axis.
//!
//! The sim subsystem's churn models decide *who* drops; this module decides
//! *when the server stops waiting* — the deployment knob that actually
//! produces timeout dropouts in the field. The pieces:
//!
//! * [`LatencyModel`] / [`ClockSpec`] — seeded per-link latency and
//!   per-client compute-delay distributions, pre-materialized by
//!   [`ClockSpec::materialize`] into a rng-free [`ClockSchedule`] (exactly
//!   like churn materializes to a `Targeted` schedule), so clocked rounds
//!   replay bit-identically and the differential shrinker keeps working;
//! * [`close_phase`] — the event queue: a binary heap over the phase's
//!   deliveries in arrival order, closed against a
//!   [`TimeoutPolicy`] deadline with a `min_survivors` grace floor. The
//!   event-loop executor calls this between the lane sweep and the server
//!   step, so a late client is dropped *exactly like a churned client*;
//! * [`run_clocked_plan`] — one clocked round plus its engine reference:
//!   the sync engine re-run with the observed timeout drops merged into the
//!   churn schedule. The clocked differential
//!   (`sim::differential`, [`super::differential::DiffSpec::Clocked`])
//!   requires the two to agree bit-for-bit, which is the literal check that
//!   timeout dropouts feed the V2/V3 survivor machinery and the Theorem-1
//!   predicate identically to churn;
//! * [`run_timeout_sweep`] — the campaign axis: reliability, privacy and
//!   simulated latency as a function of the phase deadline.
//!
//! The same [`TimeoutPolicy`] maps onto real wall-clock poll deadlines on
//! the wire executor (`net::socket`), so a policy tuned here is directly
//! deployable.

use super::campaign::{run_plan, Executor, RoundRecord};
use super::churn::ChurnModel;
use super::scenario::{
    random_scenario, AdversarySpec, CodecSpec, RoundPlan, Scenario, ThresholdRule,
    TopologySchedule,
};
use crate::protocol::dropout::DropoutModel;
use crate::protocol::{ClientId, Topology};
use crate::util::rng::Rng;
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Duration;

pub use crate::coordinator::{RoundOptions, RoundRunner, RoundTimeline, TimeoutPolicy};

/// Per-delivery link latency distribution, µs.
#[derive(Debug, Clone, PartialEq)]
pub enum LatencyModel {
    /// Every delivery lands instantly; clocked rounds degenerate to the
    /// untimed event loop unless compute delays alone cross a deadline.
    None,
    /// Uniform in `[lo_us, hi_us]` per delivery.
    Uniform { lo_us: u64, hi_us: u64 },
    /// Straggler mix: a `slow_frac` fraction of *clients* (drawn once per
    /// schedule, so a straggler is slow in every phase) deliver from the
    /// slow range; everyone else from the fast range.
    Bimodal {
        fast_lo_us: u64,
        fast_hi_us: u64,
        slow_lo_us: u64,
        slow_hi_us: u64,
        slow_frac: f64,
    },
}

/// The stochastic clock description: link latency plus a uniform per-client
/// per-phase compute delay, µs. Never consulted during a round — rounds see
/// only the materialized [`ClockSchedule`].
#[derive(Debug, Clone, PartialEq)]
pub struct ClockSpec {
    pub link: LatencyModel,
    /// Uniform compute-delay range `(lo_us, hi_us)` added to every
    /// delivery's link latency.
    pub compute_us: (u64, u64),
}

fn uniform_in(rng: &mut Rng, lo: u64, hi: u64) -> u64 {
    if hi <= lo {
        lo
    } else {
        lo + rng.gen_range(hi - lo + 1)
    }
}

impl ClockSpec {
    /// Pre-draw every (client, phase) delivery delay — client-major, with
    /// the straggler coin (if any) flipped first per client. After this the
    /// clock is pure data: identical (spec, n, seed) ⇒ identical schedule,
    /// which is what keeps clocked rounds bit-replayable.
    pub fn materialize(&self, n: usize, seed: u64) -> ClockSchedule {
        let mut rng = Rng::new(seed);
        let mut delay_us = Vec::with_capacity(n);
        for _ in 0..n {
            let slow = match self.link {
                LatencyModel::Bimodal { slow_frac, .. } => rng.bernoulli(slow_frac),
                _ => false,
            };
            let mut d = [0u64; 4];
            for slot in d.iter_mut() {
                let link = match self.link {
                    LatencyModel::None => 0,
                    LatencyModel::Uniform { lo_us, hi_us } => uniform_in(&mut rng, lo_us, hi_us),
                    LatencyModel::Bimodal {
                        fast_lo_us,
                        fast_hi_us,
                        slow_lo_us,
                        slow_hi_us,
                        ..
                    } => {
                        if slow {
                            uniform_in(&mut rng, slow_lo_us, slow_hi_us)
                        } else {
                            uniform_in(&mut rng, fast_lo_us, fast_hi_us)
                        }
                    }
                };
                let compute = uniform_in(&mut rng, self.compute_us.0, self.compute_us.1);
                *slot = link + compute;
            }
            delay_us.push(d);
        }
        ClockSchedule { delay_us }
    }
}

/// A materialized, rng-free clock: `delay_us[id][phase]` is the virtual
/// time from the phase opening to client `id`'s delivery reaching the
/// server (compute + uplink). Pure data — construct one directly for
/// hand-pinned timings.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClockSchedule {
    pub delay_us: Vec<[u64; 4]>,
}

impl ClockSchedule {
    pub fn n(&self) -> usize {
        self.delay_us.len()
    }
}

/// Outcome of closing one phase against a deadline.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseClosure {
    /// Deliveries the server accepted, sorted by id.
    pub accepted: Vec<ClientId>,
    /// Deliveries that missed the deadline — dropped like churn, sorted by id.
    pub timed_out: Vec<ClientId>,
    /// Virtual time the phase stayed open, µs.
    pub elapsed_us: u64,
}

/// Close one phase: a binary-heap event queue over the candidate
/// deliveries, ordered by (due time, id).
///
/// * deliveries due at or before the deadline are accepted;
/// * past the deadline the server keeps accepting in arrival order until
///   [`TimeoutPolicy::min_survivors`] have landed (the grace floor);
/// * everything later is timed out.
///
/// `expected` is how many clients the server is still waiting on (lanes it
/// delivered this phase's input to): when every expected client is
/// accepted, the phase closes at the last arrival; otherwise the server
/// sat out the full deadline (or the grace tail, whichever is later) — the
/// quantity the latency axis reports.
pub fn close_phase(
    phase: usize,
    candidates: &[ClientId],
    expected: usize,
    sched: &ClockSchedule,
    policy: &TimeoutPolicy,
) -> PhaseClosure {
    assert!(phase < 4, "close_phase: phase {phase} out of range (protocol has phases 0..=3)");
    let deadline_us = policy.per_phase_deadlines[phase].as_micros().min(u64::MAX as u128) as u64;
    let mut queue: BinaryHeap<Reverse<(u64, ClientId)>> = candidates
        .iter()
        .map(|&id| Reverse((sched.delay_us[id][phase], id)))
        .collect();
    let mut accepted = Vec::new();
    let mut timed_out = Vec::new();
    let mut last_accept_us = 0u64;
    while let Some(Reverse((due, id))) = queue.pop() {
        if due <= deadline_us || accepted.len() < policy.min_survivors {
            accepted.push(id);
            last_accept_us = last_accept_us.max(due);
        } else {
            timed_out.push(id);
        }
    }
    let elapsed_us = if accepted.len() == expected {
        last_accept_us
    } else {
        // someone expected never delivered in time: the server sat out the
        // deadline (or the grace tail, if the floor pulled it further)
        last_accept_us.max(deadline_us)
    };
    accepted.sort_unstable();
    timed_out.sort_unstable();
    PhaseClosure { accepted, timed_out, elapsed_us }
}

/// Salt separating per-round clock schedules from every other seed stream
/// derived from a scenario seed.
pub const CLOCK_SEED_SALT: u64 = 0xC10C_AEED;

/// The per-round clock seed: same golden-ratio round mixing as the
/// scenario's round seeds, domain-separated by [`CLOCK_SEED_SALT`].
pub fn clock_seed(seed: u64, round: usize) -> u64 {
    seed ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ CLOCK_SEED_SALT
}

/// A [`Scenario`] with a clock and a timeout policy: the clocked
/// differential's unit of work.
#[derive(Debug, Clone)]
pub struct ClockedScenario {
    pub base: Scenario,
    pub clock: ClockSpec,
    pub policy: TimeoutPolicy,
}

impl ClockedScenario {
    /// The round's materialized schedule (rng-free data, derived only from
    /// the base seed and the round index).
    pub fn schedule_for(&self, round: usize) -> ClockSchedule {
        self.clock.materialize(self.base.n, clock_seed(self.base.seed, round))
    }
}

/// Randomized clocked scenario: a [`random_scenario`] base plus a random
/// latency model and deadlines drawn to straddle it — some scenarios drop
/// no one, some drop stragglers, some abort outright. All three regimes
/// must stay bit-identical across executors.
pub fn random_clocked_scenario(seed: u64) -> ClockedScenario {
    let base = random_scenario(seed);
    let mut rng = Rng::new(seed ^ 0xC10C_0A15);
    let link = match rng.gen_range(3) {
        0 => LatencyModel::Uniform {
            lo_us: 50 + rng.gen_range(200),
            hi_us: 2_000 + rng.gen_range(8_000),
        },
        1 => LatencyModel::Bimodal {
            fast_lo_us: 50,
            fast_hi_us: 1_000,
            slow_lo_us: 5_000,
            slow_hi_us: 30_000,
            slow_frac: 0.1 + rng.next_f64() * 0.4,
        },
        _ => LatencyModel::Uniform { lo_us: 10, hi_us: 500 },
    };
    let compute_us = (10, 10 + rng.gen_range(500));
    let per_phase_deadlines =
        std::array::from_fn(|_| Duration::from_micros(200 + rng.gen_range(40_000)));
    let min_survivors = match rng.gen_range(3) {
        0 => 0,
        1 => base.n / 2,
        // floor = everyone: the grace path must accept every delivery and
        // the deadline never drops anyone
        _ => base.n,
    };
    ClockedScenario {
        base,
        clock: ClockSpec { link, compute_us },
        policy: TimeoutPolicy { per_phase_deadlines, min_survivors },
    }
}

/// Union the observed timeout drops into a compiled (rng-free) dropout
/// schedule — the reference-construction step of the clocked differential.
fn merged_dropout(base: &DropoutModel, extra: &[Vec<ClientId>; 4]) -> DropoutModel {
    let mut per_step: [Vec<ClientId>; 4] = match base {
        DropoutModel::Targeted { per_step } => per_step.clone(),
        DropoutModel::None => std::array::from_fn(|_| Vec::new()),
        DropoutModel::Iid { .. } => {
            unreachable!("clocked rounds run compiled plans, whose dropout is always rng-free")
        }
    };
    for (step, ids) in extra.iter().enumerate() {
        for &id in ids {
            if !per_step[step].contains(&id) {
                per_step[step].push(id);
            }
        }
        per_step[step].sort_unstable();
    }
    DropoutModel::Targeted { per_step }
}

/// One clocked round and its engine reference.
#[derive(Debug, Clone)]
pub struct ClockedRoundOutcome {
    /// The clocked event-loop run.
    pub clocked: RoundRecord,
    /// The sync engine re-run with the observed timeout drops merged into
    /// the churn schedule, fully scored (attack, Theorem-1, sum-vs-truth) —
    /// the reference the differential compares against, and the record the
    /// timeout sweep reads privacy off.
    pub engine: RoundRecord,
    /// What the clock observed (also present even when the round aborted).
    pub timeline: RoundTimeline,
}

/// Run one compiled round plan clocked, then build its engine reference.
///
/// The event loop decides the timeout classification *dynamically* (the
/// heap over actual deliveries); the reference is the engine with exactly
/// those drops added as churn. Identical accepted sets each phase force
/// identical server state, so the two must agree on survivor sets, sums,
/// reliability, abort behavior and logical `NetStats` — any divergence is
/// an event-loop bug (a late client charged, a dropped client still routed
/// a download, ...), which is what the clocked differential hunts.
pub fn run_clocked_plan(
    plan: &RoundPlan,
    models: &[Vec<u64>],
    sched: &Arc<ClockSchedule>,
    policy: &TimeoutPolicy,
    colluders: &[ClientId],
) -> ClockedRoundOutcome {
    assert_eq!(sched.n(), plan.cfg.n, "clock schedule population != round population");
    let opts = RoundOptions::builder()
        .executor(Executor::EventLoop)
        .timeout_policy(policy.clone())
        .clock(sched.clone())
        .build()
        .expect("event loop + clock + timeout_policy is a valid combination");
    let (res, timeline) = RoundRunner::new(opts).run_clocked(&plan.cfg, models);
    let clocked = match res {
        Ok(r) => RoundRecord {
            round: plan.round,
            aborted: false,
            reliable: r.reliable,
            sum: r.sum,
            sets: r.sets,
            stats: r.stats,
            theorem1_agrees: None,
            sum_matches_truth: None,
            breaches: 0,
            exposed_honest: 0,
        },
        Err(_) => RoundRecord::aborted(plan.round, plan.cfg.n),
    };
    let mut ref_cfg = plan.cfg.clone();
    ref_cfg.dropout = merged_dropout(&ref_cfg.dropout, &timeline.dropped);
    let ref_plan = RoundPlan { round: plan.round, cfg: ref_cfg, graph: plan.graph.clone() };
    let mut engine = run_plan(&ref_plan, models, Executor::Engine, colluders);
    // the engine has no clock, so it cannot classify the drops itself;
    // adopt the observed classification so the NetStats comparison covers
    // the timeout_drops dimension too
    if !engine.aborted {
        for (step, d) in timeline.dropped.iter().enumerate() {
            engine.stats.timeout_drops[step] = d.len() as u64;
        }
    }
    ClockedRoundOutcome { clocked, engine, timeline }
}

/// One deadline's aggregate scores in a timeout sweep.
#[derive(Debug, Clone, Default)]
pub struct SweepPoint {
    /// The uniform per-phase deadline this point ran under, µs.
    pub deadline_us: u64,
    pub rounds: usize,
    pub reliable_rounds: usize,
    pub aborted_rounds: usize,
    /// Total timeout-dropout classifications across all rounds and phases.
    pub timeout_drops: u64,
    pub breached_rounds: usize,
    pub exposed_honest: usize,
    pub theorem1_violations: usize,
    /// Mean simulated round latency, µs.
    pub mean_round_latency_us: u64,
}

/// Reliability / privacy / latency as a function of the phase deadline —
/// the campaign axis the virtual clock exists to score.
#[derive(Debug, Clone)]
pub struct TimeoutSweepReport {
    pub scenario: String,
    pub min_survivors: usize,
    pub points: Vec<SweepPoint>,
}

impl TimeoutSweepReport {
    /// Human-readable table (the `ccesa round --spec` sweep output).
    pub fn render(&self) -> String {
        let mut out = format!(
            "timeout sweep — {} (min_survivors = {})\n{:>12} {:>8} {:>9} {:>8} {:>7} {:>9} {:>8} {:>12}\n",
            self.scenario,
            self.min_survivors,
            "deadline_us",
            "rounds",
            "reliable",
            "aborted",
            "drops",
            "breached",
            "exposed",
            "latency_us",
        );
        for p in &self.points {
            out.push_str(&format!(
                "{:>12} {:>8} {:>9} {:>8} {:>7} {:>9} {:>8} {:>12}\n",
                p.deadline_us,
                p.rounds,
                p.reliable_rounds,
                p.aborted_rounds,
                p.timeout_drops,
                p.breached_rounds,
                p.exposed_honest,
                p.mean_round_latency_us,
            ));
        }
        out
    }
}

/// Sweep a scenario across per-phase deadlines: each point runs the full
/// campaign clocked (every round through [`run_clocked_plan`]) and scores
/// reliability, privacy (off the engine reference, where the Definition-2
/// attack lives) and simulated latency. Deadlines are uniform across the
/// four phases — the follow-up ROADMAP item is adaptive per-phase tuning.
pub fn run_timeout_sweep(
    sc: &Scenario,
    clock: &ClockSpec,
    deadlines_us: &[u64],
    min_survivors: usize,
) -> TimeoutSweepReport {
    let plans = sc.compile();
    let colluders = sc.adversary.colluders();
    let mut points = Vec::new();
    for &d in deadlines_us {
        let policy =
            TimeoutPolicy::uniform(Duration::from_micros(d)).with_min_survivors(min_survivors);
        let mut point = SweepPoint { deadline_us: d, rounds: plans.len(), ..Default::default() };
        let mut total_latency = 0u64;
        for plan in &plans {
            let models = sc.round_models(plan.round);
            let sched = Arc::new(clock.materialize(sc.n, clock_seed(sc.seed, plan.round)));
            let out = run_clocked_plan(plan, &models, &sched, &policy, colluders);
            point.reliable_rounds += usize::from(!out.engine.aborted && out.engine.reliable);
            point.aborted_rounds += usize::from(out.engine.aborted);
            point.timeout_drops +=
                out.timeline.dropped.iter().map(|ids| ids.len() as u64).sum::<u64>();
            point.breached_rounds += usize::from(out.engine.breaches > 0);
            point.exposed_honest += out.engine.exposed_honest;
            point.theorem1_violations += usize::from(out.engine.theorem1_agrees == Some(false));
            total_latency += out.timeline.total_us();
        }
        point.mean_round_latency_us = total_latency / plans.len().max(1) as u64;
        points.push(point);
    }
    TimeoutSweepReport { scenario: sc.name.clone(), min_survivors, points }
}

/// The CI-pinned straggler scenario: a complete graph, no churn, half the
/// cohort fast (≲2 ms), half straggling (20–40 ms), threshold above the
/// fast-cohort size. A deadline below the straggler tail drops the slow
/// half, |V1| < t and the round aborts (the Theorem-1 reliability failure);
/// a deadline past the tail keeps everyone and the round succeeds — the
/// deadline-vs-reliability tradeoff in its sharpest form.
pub fn straggler_scenario(seed: u64) -> (Scenario, ClockSpec) {
    let sc = Scenario {
        name: "straggler-tradeoff".to_string(),
        n: 12,
        dim: 8,
        mask_bits: 32,
        rounds: 3,
        topology: TopologySchedule::Static(Topology::Complete),
        churn: ChurnModel::None,
        adversary: AdversarySpec::Eavesdropper,
        threshold: ThresholdRule::Fixed(9),
        codec: CodecSpec::Dense,
        clip: 4.0,
        seed,
    };
    let clock = ClockSpec {
        link: LatencyModel::Bimodal {
            fast_lo_us: 200,
            fast_hi_us: 1_500,
            slow_lo_us: 20_000,
            slow_hi_us: 40_000,
            slow_frac: 0.5,
        },
        compute_us: (50, 300),
    };
    (sc, clock)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched_of(delays: &[[u64; 4]]) -> ClockSchedule {
        ClockSchedule { delay_us: delays.to_vec() }
    }

    #[test]
    fn materialize_is_deterministic_and_seed_sensitive() {
        let spec = ClockSpec {
            link: LatencyModel::Bimodal {
                fast_lo_us: 10,
                fast_hi_us: 100,
                slow_lo_us: 1_000,
                slow_hi_us: 2_000,
                slow_frac: 0.3,
            },
            compute_us: (5, 50),
        };
        let a = spec.materialize(20, 42);
        let b = spec.materialize(20, 42);
        assert_eq!(a, b, "identical (spec, n, seed) must materialize identically");
        let c = spec.materialize(20, 43);
        assert_ne!(a, c, "a different seed draws a different schedule");
        assert_eq!(a.n(), 20);
        for d in &a.delay_us {
            for &v in d {
                assert!((15..=2_050).contains(&v), "delay {v} outside model support");
            }
        }
    }

    #[test]
    fn close_phase_accepts_early_and_drops_late() {
        let sched = sched_of(&[[100, 0, 0, 0], [900, 0, 0, 0], [5_000, 0, 0, 0]]);
        let policy = TimeoutPolicy::uniform(Duration::from_micros(1_000));
        let c = close_phase(0, &[0, 1, 2], 3, &sched, &policy);
        assert_eq!(c.accepted, vec![0, 1]);
        assert_eq!(c.timed_out, vec![2]);
        // client 2 never delivered in time: the server sat out the deadline
        assert_eq!(c.elapsed_us, 1_000);
    }

    #[test]
    fn close_phase_without_stragglers_closes_at_last_arrival() {
        let sched = sched_of(&[[100, 0, 0, 0], [900, 0, 0, 0]]);
        let policy = TimeoutPolicy::uniform(Duration::from_micros(10_000));
        let c = close_phase(0, &[0, 1], 2, &sched, &policy);
        assert_eq!(c.accepted, vec![0, 1]);
        assert!(c.timed_out.is_empty());
        assert_eq!(c.elapsed_us, 900, "all expected delivered: phase closes at last arrival");
    }

    #[test]
    fn close_phase_grace_floor_overrides_deadline_in_arrival_order() {
        // deadline 500 would keep only client 0; a floor of 3 pulls the
        // next two arrivals (900, 2_000) past the deadline, dropping only
        // the very slowest
        let sched =
            sched_of(&[[100, 0, 0, 0], [2_000, 0, 0, 0], [900, 0, 0, 0], [7_000, 0, 0, 0]]);
        let policy =
            TimeoutPolicy::uniform(Duration::from_micros(500)).with_min_survivors(3);
        let c = close_phase(0, &[0, 1, 2, 3], 4, &sched, &policy);
        assert_eq!(c.accepted, vec![0, 1, 2]);
        assert_eq!(c.timed_out, vec![3]);
        assert_eq!(c.elapsed_us, 2_000, "the grace tail is the phase's elapsed time");
    }

    #[test]
    fn close_phase_ties_break_by_id() {
        let sched = sched_of(&[[700, 0, 0, 0], [700, 0, 0, 0], [700, 0, 0, 0]]);
        let policy = TimeoutPolicy::uniform(Duration::from_micros(0)).with_min_survivors(2);
        let c = close_phase(0, &[0, 1, 2], 3, &sched, &policy);
        // all due at 700 > deadline 0: the floor admits exactly two, and
        // the (due, id) heap order makes that deterministically ids 0, 1
        assert_eq!(c.accepted, vec![0, 1]);
        assert_eq!(c.timed_out, vec![2]);
    }

    #[test]
    fn clocked_round_with_generous_deadline_matches_untimed_loop() {
        let sc = Scenario {
            name: "clock-generous".to_string(),
            n: 10,
            dim: 6,
            mask_bits: 32,
            rounds: 1,
            topology: TopologySchedule::Static(Topology::ErdosRenyi { p: 0.8 }),
            churn: ChurnModel::Iid { q: 0.1 },
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(3),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed: 0xC10C_01,
        };
        let plans = sc.compile();
        let models = sc.round_models(0);
        let sched = Arc::new(
            ClockSpec { link: LatencyModel::Uniform { lo_us: 10, hi_us: 500 }, compute_us: (1, 20) }
                .materialize(sc.n, clock_seed(sc.seed, 0)),
        );
        let policy = TimeoutPolicy::uniform(Duration::from_secs(10));
        let out = run_clocked_plan(&plans[0], &models, &sched, &policy, &[]);
        assert!(!out.timeline.dropped_any(), "a 10 s deadline drops no one");
        assert_eq!(out.clocked.stats.timeout_drops, [0; 4]);
        // with no timeout drops the reference is the plain engine round
        let plain = run_plan(&plans[0], &models, Executor::EventLoop, &[]);
        assert_eq!(out.clocked.sets, plain.sets);
        assert_eq!(out.clocked.sum, plain.sum);
        assert_eq!(out.clocked.stats, plain.stats);
        assert!(out.timeline.total_us() > 0, "the phases still took virtual time");
    }

    #[test]
    fn clocked_rounds_replay_bit_identically() {
        let csc = random_clocked_scenario(0xC10C_42);
        let plans = csc.base.compile();
        let models = csc.base.round_models(plans[0].round);
        let sched = Arc::new(csc.schedule_for(plans[0].round));
        let a = run_clocked_plan(&plans[0], &models, &sched, &csc.policy, &[]);
        let b = run_clocked_plan(&plans[0], &models, &sched, &csc.policy, &[]);
        assert_eq!(a.timeline, b.timeline, "identical seed ⇒ identical timeline");
        assert_eq!(a.clocked, b.clocked, "identical seed ⇒ identical record");
    }

    #[test]
    fn hand_pinned_straggler_drops_exactly_like_churn() {
        // 6 clients, complete graph, no churn; client 5 is 50 ms slow in
        // phase 2 only. A 1 ms deadline must classify exactly {5} at phase
        // 2, and the engine with churn {5}@step2 must agree bit-for-bit.
        let sc = Scenario {
            name: "pinned-straggler".to_string(),
            n: 6,
            dim: 4,
            mask_bits: 32,
            rounds: 1,
            topology: TopologySchedule::Static(Topology::Complete),
            churn: ChurnModel::None,
            adversary: AdversarySpec::Eavesdropper,
            threshold: ThresholdRule::Fixed(3),
            codec: CodecSpec::Dense,
            clip: 4.0,
            seed: 0x5712A,
        };
        let plans = sc.compile();
        let models = sc.round_models(0);
        let mut delays = vec![[100u64; 4]; 6];
        delays[5][2] = 50_000;
        let sched = Arc::new(ClockSchedule { delay_us: delays });
        let policy = TimeoutPolicy::uniform(Duration::from_millis(1));
        let out = run_clocked_plan(&plans[0], &models, &sched, &policy, &[]);
        assert_eq!(out.timeline.dropped[2], vec![5]);
        assert_eq!(out.clocked.stats.timeout_drops, [0, 0, 1, 0]);
        assert!(!out.clocked.aborted && out.clocked.reliable);
        assert!(!out.clocked.sets.v3.contains(&5), "5 is out of V3, like churn");
        assert!(out.clocked.sets.v2.contains(&5), "5 delivered phases 0–1 on time");
        // the merged-schedule engine agrees on every compared field
        assert_eq!(out.engine.sets, out.clocked.sets);
        assert_eq!(out.engine.sum, out.clocked.sum);
        assert!(out.engine.stats.logical_eq(&out.clocked.stats));
        assert_eq!(out.engine.stats.timeout_drops, [0, 0, 1, 0]);
        // phase 2 sat out its full deadline; the other phases closed at
        // the last arrival
        assert_eq!(out.timeline.phase_elapsed_us, [100, 100, 1_000, 100]);
    }

    #[test]
    fn sweep_reports_the_deadline_tradeoff() {
        let (sc, clock) = straggler_scenario(0x51EE9);
        let report = run_timeout_sweep(&sc, &clock, &[5_000, 100_000], 0);
        assert_eq!(report.points.len(), 2);
        let short = &report.points[0];
        let long = &report.points[1];
        assert_eq!(short.rounds, 3);
        assert!(
            short.reliable_rounds < long.reliable_rounds,
            "short {short:?} vs long {long:?}"
        );
        assert_eq!(long.reliable_rounds, 3, "past the straggler tail every round succeeds");
        assert_eq!(long.timeout_drops, 0);
        assert!(short.timeout_drops > 0, "the short deadline dropped stragglers");
        assert!(
            short.mean_round_latency_us < long.mean_round_latency_us,
            "waiting out stragglers costs latency: {} vs {}",
            short.mean_round_latency_us,
            long.mean_round_latency_us
        );
        let rendered = report.render();
        assert!(rendered.contains("straggler-tradeoff"));
        assert!(rendered.lines().count() >= 4);
    }
}
