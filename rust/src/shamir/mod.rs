//! Shamir t-out-of-n secret sharing over GF(2^16) (Shamir, 1979).
//!
//! The protocol shares two 32-byte secrets per client (Algorithm 1 Step 1):
//! the PRG seed `b_i` and the mask secret key `s_i^SK`. A secret of K bytes
//! is chunked into ⌈K/2⌉ u16 field elements; each chunk gets an independent
//! degree-(t−1) polynomial whose constant term is the chunk. The share for
//! holder with nonzero evaluation point `x` is the vector of polynomial
//! evaluations at `x`.
//!
//! Properties (and the tests that pin them):
//! * any `t` distinct shares reconstruct exactly (Lagrange at 0);
//! * any `t−1` shares are statistically independent of the secret —
//!   verified by showing every candidate secret value remains consistent;
//! * evaluation points are `client_id + 1` so they never collide with 0.

use crate::gf::gf65536 as gf;
use crate::util::rng::Rng;
use thiserror::Error;

/// One holder's share of a byte-secret.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Share {
    /// Evaluation point (nonzero).
    pub x: u16,
    /// Evaluations of each chunk polynomial at `x`.
    pub y: Vec<u16>,
}

impl Share {
    /// Serialized size in bytes (for communication accounting):
    /// 2 bytes for x + 2 per chunk.
    pub fn size_bytes(&self) -> usize {
        2 + 2 * self.y.len()
    }

    /// Flatten to bytes (x || y little-endian) — the AEAD plaintext format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.size_bytes());
        out.extend_from_slice(&self.x.to_le_bytes());
        for v in &self.y {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<Share, ShamirError> {
        if b.len() < 2 || b.len() % 2 != 0 {
            return Err(ShamirError::Malformed);
        }
        let x = u16::from_le_bytes([b[0], b[1]]);
        if x == 0 {
            return Err(ShamirError::Malformed);
        }
        let y = b[2..]
            .chunks_exact(2)
            .map(|c| u16::from_le_bytes([c[0], c[1]]))
            .collect();
        Ok(Share { x, y })
    }
}

#[derive(Debug, Error, PartialEq, Eq)]
pub enum ShamirError {
    #[error("need at least t={t} shares, got {got}")]
    NotEnoughShares { t: usize, got: usize },
    #[error("duplicate evaluation point {x}")]
    DuplicatePoint { x: u16 },
    #[error("shares have inconsistent lengths")]
    InconsistentLengths,
    #[error("threshold must satisfy 1 <= t <= n <= 65535")]
    BadParameters,
    #[error("malformed share encoding")]
    Malformed,
}

/// Pack bytes into u16 chunks (little-endian, zero-padded).
fn to_chunks(secret: &[u8]) -> Vec<u16> {
    secret
        .chunks(2)
        .map(|c| {
            let lo = c[0] as u16;
            let hi = if c.len() > 1 { c[1] as u16 } else { 0 };
            lo | (hi << 8)
        })
        .collect()
}

/// Unpack u16 chunks back into `len` bytes.
fn from_chunks(chunks: &[u16], len: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity(len);
    for c in chunks {
        out.push((*c & 0xFF) as u8);
        out.push((*c >> 8) as u8);
    }
    out.truncate(len);
    out
}

/// Split `secret` into shares at the given evaluation points with
/// threshold `t`. Points must be nonzero and distinct.
pub fn split(
    secret: &[u8],
    t: usize,
    points: &[u16],
    rng: &mut Rng,
) -> Result<Vec<Share>, ShamirError> {
    let n = points.len();
    if t == 0 || t > n || n > 65535 {
        return Err(ShamirError::BadParameters);
    }
    {
        let mut seen = std::collections::HashSet::with_capacity(n);
        for &x in points {
            if x == 0 || !seen.insert(x) {
                return Err(if x == 0 {
                    ShamirError::BadParameters
                } else {
                    ShamirError::DuplicatePoint { x }
                });
            }
        }
    }
    let chunks = to_chunks(secret);
    let m = chunks.len();
    // rows[k][c] = coefficient of x^k for chunk c (row 0 is the secret).
    // Degree-major storage lets evaluation run whole-row Horner steps
    // through the vector kernels; the RNG is still drawn chunk-major —
    // every coefficient of chunk c before any of chunk c+1 — the exact
    // order the per-chunk splitter used, so shares are bit-identical for a
    // given RNG state (the wire-contract golden tests pin this).
    let mut rows: Vec<Vec<u16>> = Vec::with_capacity(t);
    rows.push(chunks);
    for _ in 1..t {
        rows.push(vec![0u16; m]);
    }
    for c in 0..m {
        for row in rows.iter_mut().skip(1) {
            row[c] = rng.next_u32() as u16;
        }
    }
    Ok(points
        .iter()
        .map(|&x| {
            // Vectorized Horner across all chunk polynomials at once: per
            // degree, one slice-by-constant multiply (`kernels`) plus one
            // row XOR — same per-element operations as scalar Horner.
            let mut y = rows[t - 1].clone();
            for row in rows[..t - 1].iter().rev() {
                crate::kernels::gf_mul_slice_const(&mut y, x);
                for (a, &c) in y.iter_mut().zip(row) {
                    *a = gf::add(*a, c);
                }
            }
            Share { x, y }
        })
        .collect())
}

/// Precomputed Lagrange interpolation weights at x = 0 for one fixed,
/// ordered holder set.
///
/// Computing the weights is the O(t²) part of reconstruction; applying
/// them to a share vector is O(t·m). In the server's Step-3 hot path many
/// owners share the *same* holder set (every surviving neighbor sent its
/// share), so [`reconstruct_batch`] computes one basis per distinct set and
/// reuses it across all owners — and within one owner across all ⌈K/2⌉
/// secret chunks.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LagrangeBasis {
    /// Evaluation points, in the order shares must be supplied.
    xs: Vec<u16>,
    /// weights[i] = Π_{j≠i} x_j / (x_j − x_i); in GF(2^k) subtraction is
    /// XOR.
    weights: Vec<u16>,
}

impl LagrangeBasis {
    /// Build the basis at x = 0 for the given (distinct, nonzero, ordered)
    /// evaluation points.
    pub fn at_zero(points: &[u16]) -> Result<LagrangeBasis, ShamirError> {
        if points.is_empty() {
            return Err(ShamirError::BadParameters);
        }
        {
            let mut seen = std::collections::HashSet::with_capacity(points.len());
            for &x in points {
                if x == 0 {
                    return Err(ShamirError::BadParameters);
                }
                if !seen.insert(x) {
                    return Err(ShamirError::DuplicatePoint { x });
                }
            }
        }
        let t = points.len();
        let mut weights = vec![0u16; t];
        for i in 0..t {
            let mut num = 1u16;
            let mut den = 1u16;
            for j in 0..t {
                if i != j {
                    num = gf::mul(num, points[j]);
                    den = gf::mul(den, gf::add(points[j], points[i]));
                }
            }
            weights[i] = gf::div(num, den);
        }
        Ok(LagrangeBasis { xs: points.to_vec(), weights })
    }

    /// The evaluation points this basis interpolates, in supply order.
    pub fn points(&self) -> &[u16] {
        &self.xs
    }

    /// Interpolate a `secret_len`-byte secret from shares aligned with
    /// [`LagrangeBasis::points`] (same x's, same order).
    pub fn reconstruct(
        &self,
        shares: &[Share],
        secret_len: usize,
    ) -> Result<Vec<u8>, ShamirError> {
        let t = self.xs.len();
        if shares.len() != t {
            return Err(ShamirError::NotEnoughShares { t, got: shares.len() });
        }
        let m = shares[0].y.len();
        if shares.iter().any(|s| s.y.len() != m) {
            return Err(ShamirError::InconsistentLengths);
        }
        for (s, &x) in shares.iter().zip(&self.xs) {
            if s.x != x {
                return Err(ShamirError::BadParameters);
            }
        }
        // Step-3 weight application: one vectorized multiply-accumulate
        // per share vector (`kernels::gf_fma_slice`).
        let mut chunks = vec![0u16; m];
        for (share, &li) in shares.iter().zip(&self.weights) {
            crate::kernels::gf_fma_slice(&mut chunks, &share.y, li);
        }
        Ok(from_chunks(&chunks, secret_len))
    }
}

/// Reconstruct a `secret_len`-byte secret from at least `t` shares.
///
/// Exactly the first `t` distinct shares are used (Lagrange interpolation
/// at x = 0). Extra shares are ignored — reconstruction cost is O(t²+t·m),
/// which matters for the server's Step-3 hot path; when many owners share
/// a holder set, [`reconstruct_batch`] amortizes the O(t²) part.
pub fn reconstruct(
    shares: &[Share],
    t: usize,
    secret_len: usize,
) -> Result<Vec<u8>, ShamirError> {
    if t == 0 {
        return Err(ShamirError::BadParameters);
    }
    if shares.len() < t {
        return Err(ShamirError::NotEnoughShares { t, got: shares.len() });
    }
    let used = &shares[..t];
    let points: Vec<u16> = used.iter().map(|s| s.x).collect();
    let basis = LagrangeBasis::at_zero(&points)?;
    basis.reconstruct(used, secret_len)
}

/// Result of a batched reconstruction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchReconstruction {
    /// One secret per job, in job order — each bit-identical to what the
    /// per-owner [`reconstruct`] returns for the same shares.
    pub secrets: Vec<Vec<u8>>,
    /// How many distinct Lagrange bases were computed — exactly one per
    /// distinct (ordered) holder set among the jobs. The unmasking tests
    /// assert on this: mixed holder sets must never share a basis, and
    /// identical holder sets must never recompute one.
    pub bases_computed: usize,
}

/// Reconstruct many `secret_len`-byte secrets at once, grouping jobs by
/// identical holder set (the first `t` shares' evaluation points, in
/// order) and computing one Lagrange basis per group.
///
/// In the server's Step-3 regime — n owners whose shares arrive from the
/// same V4 survivors — this collapses n O(t²) basis solves into one,
/// leaving n·O(t·m) weight applications, and those run *group-wide*: per
/// Lagrange weight, every member job's share vector is applied in one
/// `kernels::gf_fma_slice` call over their concatenation, so the vector
/// backends see slices of m·|group| elements instead of m (XOR
/// accumulation is exact, so this is bit-identical to the per-owner
/// path). Falls back gracefully: jobs with unique holder sets each get
/// their own basis and cost exactly the per-owner path.
pub fn reconstruct_batch(
    jobs: &[&[Share]],
    t: usize,
    secret_len: usize,
) -> Result<BatchReconstruction, ShamirError> {
    if t == 0 {
        return Err(ShamirError::BadParameters);
    }
    // ---- Plan, in job order (error precedence preserved): validate every
    // job and dedup Lagrange bases by (ordered) holder set.
    let mut bases: Vec<LagrangeBasis> = Vec::new();
    let mut by_points: std::collections::HashMap<Vec<u16>, usize> =
        std::collections::HashMap::new();
    let mut job_basis: Vec<usize> = Vec::with_capacity(jobs.len());
    let mut job_m: Vec<usize> = Vec::with_capacity(jobs.len());
    for shares in jobs {
        if shares.len() < t {
            return Err(ShamirError::NotEnoughShares { t, got: shares.len() });
        }
        let used = &shares[..t];
        let m = used[0].y.len();
        if used.iter().any(|s| s.y.len() != m) {
            return Err(ShamirError::InconsistentLengths);
        }
        let points: Vec<u16> = used.iter().map(|s| s.x).collect();
        let idx = match by_points.get(&points) {
            Some(&idx) => idx,
            None => {
                let basis = LagrangeBasis::at_zero(&points)?;
                bases.push(basis);
                by_points.insert(points, bases.len() - 1);
                bases.len() - 1
            }
        };
        job_basis.push(idx);
        job_m.push(m);
    }

    // ---- Execute per (basis, share-vector-length) group. Jobs are
    // sub-grouped by m so the concatenation stays rectangular; mixed-m
    // groups only arise from malformed shares and just split into smaller
    // groups. Group processing order does not matter — jobs are disjoint.
    let mut secrets: Vec<Vec<u8>> = vec![Vec::new(); jobs.len()];
    let mut groups: std::collections::HashMap<(usize, usize), Vec<usize>> =
        std::collections::HashMap::new();
    for (j, (&b, &m)) in job_basis.iter().zip(job_m.iter()).enumerate() {
        groups.entry((b, m)).or_default().push(j);
    }
    for ((bidx, m), members) in groups {
        let weights = &bases[bidx].weights;
        let mut acc = vec![0u16; m * members.len()];
        let mut row = vec![0u16; m * members.len()];
        for (i, &w) in weights.iter().enumerate() {
            for (slot, &j) in members.iter().enumerate() {
                row[slot * m..(slot + 1) * m].copy_from_slice(&jobs[j][i].y);
            }
            crate::kernels::gf_fma_slice(&mut acc, &row, w);
        }
        for (slot, &j) in members.iter().enumerate() {
            secrets[j] = from_chunks(&acc[slot * m..(slot + 1) * m], secret_len);
        }
    }
    Ok(BatchReconstruction { secrets, bases_computed: bases.len() })
}

/// Standard evaluation point for a client id (id + 1, avoiding 0).
#[inline]
pub fn point_for_client(client_id: usize) -> u16 {
    u16::try_from(client_id + 1).expect("client id exceeds GF(2^16) capacity")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(0x5A3)
    }

    #[test]
    fn round_trip_exact_threshold() {
        let mut r = rng();
        let secret = b"a 32-byte secret for ccesa tests";
        let points: Vec<u16> = (1..=10).collect();
        let shares = split(secret, 4, &points, &mut r).unwrap();
        assert_eq!(shares.len(), 10);
        let rec = reconstruct(&shares[..4], 4, secret.len()).unwrap();
        assert_eq!(rec, secret.to_vec());
    }

    #[test]
    fn any_t_subset_reconstructs() {
        let mut r = rng();
        let secret = [7u8; 32];
        let points: Vec<u16> = (1..=8).collect();
        let t = 3;
        let shares = split(&secret, t, &points, &mut r).unwrap();
        // try several subsets including non-contiguous ones
        for subset in [[0usize, 1, 2], [5, 2, 7], [7, 6, 5], [0, 4, 7]] {
            let picked: Vec<Share> = subset.iter().map(|&i| shares[i].clone()).collect();
            assert_eq!(reconstruct(&picked, t, 32).unwrap(), secret.to_vec());
        }
    }

    #[test]
    fn fewer_than_t_fails() {
        let mut r = rng();
        let shares = split(b"secret", 3, &[1, 2, 3, 4], &mut r).unwrap();
        assert_eq!(
            reconstruct(&shares[..2], 3, 6),
            Err(ShamirError::NotEnoughShares { t: 3, got: 2 })
        );
    }

    #[test]
    fn t_minus_one_shares_leak_nothing() {
        // With t-1 shares, every possible first-chunk value must remain
        // consistent with SOME polynomial — check via a degree argument:
        // interpolating (t-1) points plus a guessed (0, guess) point always
        // succeeds with a degree-(t-1) polynomial, so all guesses are
        // equally plausible. We verify that reconstructing from t-1 real
        // shares plus a forged share at a fresh x yields a *different*
        // secret for different forgeries (i.e. the real shares do not pin
        // the secret down).
        let mut r = rng();
        let secret = b"pq";
        let t = 3;
        let shares = split(secret, t, &[1, 2, 3, 4, 5], &mut r).unwrap();
        let mut results = std::collections::HashSet::new();
        for forged_y in [0u16, 1, 0xBEEF, 0xFFFF] {
            let forged = Share { x: 9, y: vec![forged_y] };
            let picked = vec![shares[0].clone(), shares[1].clone(), forged];
            results.insert(reconstruct(&picked, t, 2).unwrap());
        }
        assert_eq!(results.len(), 4, "t-1 shares must not determine the secret");
    }

    #[test]
    fn one_out_of_n_is_plaintext_of_degree_zero() {
        let mut r = rng();
        let secret = b"x";
        let shares = split(secret, 1, &[5, 9], &mut r).unwrap();
        // t=1: polynomial is constant, every share equals the secret chunk
        assert_eq!(reconstruct(&shares[..1], 1, 1).unwrap(), secret.to_vec());
        assert_eq!(shares[0].y, shares[1].y);
    }

    #[test]
    fn rejects_bad_parameters() {
        let mut r = rng();
        assert_eq!(split(b"s", 0, &[1], &mut r), Err(ShamirError::BadParameters));
        assert_eq!(split(b"s", 3, &[1, 2], &mut r), Err(ShamirError::BadParameters));
        assert_eq!(split(b"s", 1, &[0], &mut r), Err(ShamirError::BadParameters));
        assert_eq!(
            split(b"s", 2, &[1, 1], &mut r),
            Err(ShamirError::DuplicatePoint { x: 1 })
        );
    }

    #[test]
    fn rejects_duplicate_points_on_reconstruct() {
        let mut r = rng();
        let shares = split(b"secret!!", 2, &[1, 2, 3], &mut r).unwrap();
        let dup = vec![shares[0].clone(), shares[0].clone()];
        assert_eq!(
            reconstruct(&dup, 2, 8),
            Err(ShamirError::DuplicatePoint { x: 1 })
        );
    }

    #[test]
    fn odd_length_secrets() {
        let mut r = rng();
        for len in [1usize, 3, 31, 33] {
            let secret: Vec<u8> = (0..len as u8).collect();
            let shares = split(&secret, 2, &[1, 2, 3], &mut r).unwrap();
            assert_eq!(reconstruct(&shares[1..], 2, len).unwrap(), secret, "len={len}");
        }
    }

    #[test]
    fn share_byte_encoding_round_trip() {
        let mut r = rng();
        let shares = split(&[9u8; 32], 2, &[1, 2], &mut r).unwrap();
        for s in &shares {
            let b = s.to_bytes();
            assert_eq!(b.len(), s.size_bytes());
            assert_eq!(Share::from_bytes(&b).unwrap(), *s);
        }
        assert_eq!(Share::from_bytes(&[0, 0, 1, 0]), Err(ShamirError::Malformed)); // x=0
        assert_eq!(Share::from_bytes(&[1]), Err(ShamirError::Malformed));
    }

    #[test]
    fn property_random_instances() {
        // randomized property: for random (n, t, secret), any t random
        // shares reconstruct; t-1 with a forged share do not (w.h.p.).
        let mut r = Rng::new(0xFACE);
        for trial in 0..25 {
            let n = 2 + (r.gen_range(30) as usize);
            let t = 1 + (r.gen_range(n as u64) as usize);
            let len = 1 + (r.gen_range(40) as usize);
            let mut secret = vec![0u8; len];
            r.fill_bytes(&mut secret);
            let points: Vec<u16> = (1..=n as u16).collect();
            let shares = split(&secret, t, &points, &mut r).unwrap();
            let idx = r.sample_indices(n, t);
            let picked: Vec<Share> = idx.iter().map(|&i| shares[i].clone()).collect();
            assert_eq!(
                reconstruct(&picked, t, len).unwrap(),
                secret,
                "trial={trial} n={n} t={t}"
            );
        }
    }

    #[test]
    fn lagrange_basis_matches_reconstruct() {
        let mut r = rng();
        let secret = b"basis equality secret 0123456789";
        let points: Vec<u16> = (1..=9).collect();
        let t = 5;
        let shares = split(secret, t, &points, &mut r).unwrap();
        let xs: Vec<u16> = shares[..t].iter().map(|s| s.x).collect();
        let basis = LagrangeBasis::at_zero(&xs).unwrap();
        assert_eq!(basis.points(), &xs[..]);
        assert_eq!(
            basis.reconstruct(&shares[..t], secret.len()).unwrap(),
            reconstruct(&shares[..t], t, secret.len()).unwrap()
        );
        // misaligned shares are rejected, not silently mis-weighted
        let mut wrong_order: Vec<Share> = shares[..t].to_vec();
        wrong_order.swap(0, 1);
        assert_eq!(
            basis.reconstruct(&wrong_order, secret.len()),
            Err(ShamirError::BadParameters)
        );
    }

    #[test]
    fn lagrange_basis_rejects_bad_points() {
        assert_eq!(LagrangeBasis::at_zero(&[]), Err(ShamirError::BadParameters));
        assert_eq!(LagrangeBasis::at_zero(&[1, 0]), Err(ShamirError::BadParameters));
        assert_eq!(
            LagrangeBasis::at_zero(&[3, 3]),
            Err(ShamirError::DuplicatePoint { x: 3 })
        );
    }

    #[test]
    fn batch_matches_individual_across_random_groupings() {
        // randomized property: owners with randomized holder subsets —
        // some identical, some distinct — reconstruct identically through
        // the batched and the per-owner paths, and the batch computes
        // exactly one basis per distinct holder set
        let mut r = Rng::new(0xBA7C);
        for trial in 0..15 {
            let n = 6 + (r.gen_range(12) as usize);
            let t = 2 + (r.gen_range((n - 2) as u64) as usize);
            let owners = 3 + (r.gen_range(6) as usize);
            let points: Vec<u16> = (1..=n as u16).collect();
            // a small pool of holder subsets; owners draw from it so some
            // groups repeat
            let pool: Vec<Vec<usize>> =
                (0..3).map(|_| r.sample_indices(n, t)).collect();
            let mut jobs_owned: Vec<Vec<Share>> = Vec::new();
            let mut secrets_truth: Vec<Vec<u8>> = Vec::new();
            let mut distinct: std::collections::BTreeSet<Vec<u16>> =
                std::collections::BTreeSet::new();
            for _ in 0..owners {
                let mut secret = vec![0u8; 32];
                r.fill_bytes(&mut secret);
                let shares = split(&secret, t, &points, &mut r).unwrap();
                let subset = &pool[r.gen_range(3) as usize];
                let picked: Vec<Share> =
                    subset.iter().map(|&i| shares[i].clone()).collect();
                distinct.insert(picked.iter().map(|s| s.x).collect());
                jobs_owned.push(picked);
                secrets_truth.push(secret);
            }
            let jobs: Vec<&[Share]> = jobs_owned.iter().map(|j| j.as_slice()).collect();
            let batch = reconstruct_batch(&jobs, t, 32).unwrap();
            assert_eq!(batch.secrets.len(), owners, "trial={trial}");
            for (k, job) in jobs.iter().enumerate() {
                assert_eq!(batch.secrets[k], secrets_truth[k], "trial={trial} owner={k}");
                assert_eq!(
                    batch.secrets[k],
                    reconstruct(job, t, 32).unwrap(),
                    "trial={trial} owner={k}"
                );
            }
            assert_eq!(
                batch.bases_computed,
                distinct.len(),
                "trial={trial}: one basis per distinct holder set"
            );
        }
    }

    #[test]
    fn mixed_holder_sets_never_share_a_basis() {
        // regression: two owners whose holder sets differ (even by order)
        // must get separate bases; identical sets must share one
        let mut r = rng();
        let points: Vec<u16> = (1..=8).collect();
        let t = 3;
        let s1 = split(&[1u8; 32], t, &points, &mut r).unwrap();
        let s2 = split(&[2u8; 32], t, &points, &mut r).unwrap();
        let s3 = split(&[3u8; 32], t, &points, &mut r).unwrap();

        // same holder set {1,2,3} for owners 1 and 2 → one basis
        let same = reconstruct_batch(&[&s1[..3], &s2[..3]], t, 32).unwrap();
        assert_eq!(same.bases_computed, 1);
        assert_eq!(same.secrets[0], vec![1u8; 32]);
        assert_eq!(same.secrets[1], vec![2u8; 32]);

        // different holder sets {1,2,3} vs {4,5,6} → two bases
        let mixed = reconstruct_batch(&[&s1[..3], &s3[3..6]], t, 32).unwrap();
        assert_eq!(mixed.bases_computed, 2);
        assert_eq!(mixed.secrets[1], vec![3u8; 32]);

        // same set, different supply order → separate (order-keyed) bases,
        // still exact
        let reordered: Vec<Share> = vec![s2[2].clone(), s2[0].clone(), s2[1].clone()];
        let ord = reconstruct_batch(&[&s1[..3], &reordered[..]], t, 32).unwrap();
        assert_eq!(ord.bases_computed, 2);
        assert_eq!(ord.secrets[1], vec![2u8; 32]);

        // errors propagate: short job
        assert_eq!(
            reconstruct_batch(&[&s1[..2]], t, 32),
            Err(ShamirError::NotEnoughShares { t: 3, got: 2 })
        );
        // empty batch is fine and computes nothing
        let empty = reconstruct_batch(&[], t, 32).unwrap();
        assert_eq!(empty.bases_computed, 0);
        assert!(empty.secrets.is_empty());
    }

    #[test]
    fn batch_handles_mixed_share_vector_lengths() {
        // regression for the group-concatenated weight application: two
        // jobs sharing one holder set but with different y-lengths (a
        // malformed/truncated share set) must still match the per-owner
        // path element for element — they land in separate (basis, m)
        // sub-groups but share the one basis
        let mut r = rng();
        let points: Vec<u16> = (1..=6).collect();
        let t = 3;
        let full = split(&[0x42u8; 32], t, &points, &mut r).unwrap();
        let truncated: Vec<Share> = split(&[0x77u8; 32], t, &points, &mut r)
            .unwrap()
            .into_iter()
            .map(|s| Share { x: s.x, y: s.y[..8].to_vec() })
            .collect();
        let jobs: Vec<&[Share]> = vec![&full[..t], &truncated[..t]];
        let batch = reconstruct_batch(&jobs, t, 32).unwrap();
        assert_eq!(batch.bases_computed, 1, "same holder set, one basis");
        for (k, job) in jobs.iter().enumerate() {
            assert_eq!(batch.secrets[k], reconstruct(job, t, 32).unwrap(), "job {k}");
        }
        // the truncated job reconstructs a short secret, as before
        assert_eq!(batch.secrets[1].len(), 16);
        assert_eq!(batch.secrets[0], vec![0x42u8; 32]);
    }

    #[test]
    fn large_n_1000_holders() {
        // the Fig 5.2 regime: n=1000 share holders, t=311
        let mut r = rng();
        let secret = [0xA5u8; 32];
        let points: Vec<u16> = (1..=1000).collect();
        let t = 311;
        let shares = split(&secret, t, &points, &mut r).unwrap();
        let rec = reconstruct(&shares[689..], t, 32).unwrap();
        assert_eq!(rec, secret.to_vec());
    }
}
