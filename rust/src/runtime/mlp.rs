//! MLP model runtime: typed wrapper over the `mlp_train` / `mlp_eval`
//! HLO executables, plus the flat-parameter view used by secure
//! aggregation (quantize → mask → aggregate → dequantize operates on the
//! flattened f32 vector).

use super::{scalar_f32, to_f32, to_i32, HloExecutable, Input, MlpDims, Runtime};
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// MLP parameters (w1, b1, w2, b2) in row-major order.
#[derive(Debug, Clone, PartialEq)]
pub struct MlpParams {
    pub dims: MlpDims,
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

impl MlpParams {
    /// He/Xavier-style init, deterministic in `rng`.
    pub fn init(dims: MlpDims, rng: &mut Rng) -> MlpParams {
        let s1 = (2.0 / dims.d as f32).sqrt();
        let s2 = (1.0 / dims.h as f32).sqrt();
        MlpParams {
            dims,
            w1: (0..dims.d * dims.h).map(|_| rng.normal_f32(0.0, s1)).collect(),
            b1: vec![0.0; dims.h],
            w2: (0..dims.h * dims.c).map(|_| rng.normal_f32(0.0, s2)).collect(),
            b2: vec![0.0; dims.c],
        }
    }

    pub fn zeros(dims: MlpDims) -> MlpParams {
        MlpParams {
            dims,
            w1: vec![0.0; dims.d * dims.h],
            b1: vec![0.0; dims.h],
            w2: vec![0.0; dims.h * dims.c],
            b2: vec![0.0; dims.c],
        }
    }

    /// Flatten to a single vector (the secure-aggregation payload).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.dims.param_count());
        out.extend_from_slice(&self.w1);
        out.extend_from_slice(&self.b1);
        out.extend_from_slice(&self.w2);
        out.extend_from_slice(&self.b2);
        out
    }

    /// Rebuild from a flat vector.
    pub fn from_flat(dims: MlpDims, flat: &[f32]) -> Result<MlpParams> {
        if flat.len() != dims.param_count() {
            bail!("flat length {} != param count {}", flat.len(), dims.param_count());
        }
        let (a, rest) = flat.split_at(dims.d * dims.h);
        let (b, rest) = rest.split_at(dims.h);
        let (c, d) = rest.split_at(dims.h * dims.c);
        Ok(MlpParams {
            dims,
            w1: a.to_vec(),
            b1: b.to_vec(),
            w2: c.to_vec(),
            b2: d.to_vec(),
        })
    }
}

/// Compiled MLP executables.
pub struct MlpRuntime {
    pub dims: MlpDims,
    train: HloExecutable,
    eval: HloExecutable,
}

impl MlpRuntime {
    pub fn load(rt: &Runtime) -> Result<MlpRuntime> {
        Ok(MlpRuntime {
            dims: rt.manifest.mlp_dims(),
            train: rt.load("mlp_train")?,
            eval: rt.load("mlp_eval")?,
        })
    }

    fn param_inputs(&self, p: &MlpParams) -> Vec<Input> {
        let d = self.dims;
        vec![
            Input::F32(p.w1.clone(), vec![d.d as i64, d.h as i64]),
            Input::F32(p.b1.clone(), vec![d.h as i64]),
            Input::F32(p.w2.clone(), vec![d.h as i64, d.c as i64]),
            Input::F32(p.b2.clone(), vec![d.c as i64]),
        ]
    }

    /// One SGD step over a batch; updates `p` in place and returns the loss.
    /// `x`: batch·d features, `y_onehot`: batch·c.
    pub fn train_step(
        &self,
        p: &mut MlpParams,
        x: &[f32],
        y_onehot: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let d = self.dims;
        if x.len() != d.batch * d.d || y_onehot.len() != d.batch * d.c {
            bail!("train batch shape mismatch");
        }
        let mut inputs = self.param_inputs(p);
        inputs.push(Input::F32(x.to_vec(), vec![d.batch as i64, d.d as i64]));
        inputs.push(Input::F32(y_onehot.to_vec(), vec![d.batch as i64, d.c as i64]));
        inputs.push(Input::ScalarF32(lr));
        let outs = self.train.run(&inputs)?;
        p.w1 = to_f32(&outs[0])?;
        p.b1 = to_f32(&outs[1])?;
        p.w2 = to_f32(&outs[2])?;
        p.b2 = to_f32(&outs[3])?;
        scalar_f32(&outs[4])
    }

    /// Count correct predictions over one batch.
    pub fn eval_batch(&self, p: &MlpParams, x: &[f32], labels: &[i32]) -> Result<usize> {
        let d = self.dims;
        if x.len() != d.batch * d.d || labels.len() != d.batch {
            bail!("eval batch shape mismatch");
        }
        let mut inputs = self.param_inputs(p);
        inputs.push(Input::F32(x.to_vec(), vec![d.batch as i64, d.d as i64]));
        inputs.push(Input::I32(labels.to_vec(), vec![d.batch as i64]));
        let outs = self.eval.run(&inputs)?;
        Ok(to_i32(&outs[0])?[0] as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> MlpDims {
        MlpDims { batch: 32, d: 192, h: 256, c: 10 }
    }

    #[test]
    fn flatten_round_trip() {
        let mut rng = Rng::new(5);
        let p = MlpParams::init(dims(), &mut rng);
        let flat = p.flatten();
        assert_eq!(flat.len(), dims().param_count());
        let q = MlpParams::from_flat(dims(), &flat).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn from_flat_rejects_bad_length() {
        assert!(MlpParams::from_flat(dims(), &[0.0; 7]).is_err());
    }

    #[test]
    fn init_is_scaled_and_deterministic() {
        let p1 = MlpParams::init(dims(), &mut Rng::new(1));
        let p2 = MlpParams::init(dims(), &mut Rng::new(1));
        assert_eq!(p1, p2);
        let var: f32 =
            p1.w1.iter().map(|x| x * x).sum::<f32>() / p1.w1.len() as f32;
        let expect = 2.0 / dims().d as f32;
        assert!((var - expect).abs() < 0.3 * expect, "var={var} expect={expect}");
        assert!(p1.b1.iter().all(|&b| b == 0.0));
    }
}
