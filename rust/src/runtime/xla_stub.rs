//! Hermetic stand-in for the external `xla` (PJRT) crate.
//!
//! The default build must work in environments without the XLA C library or
//! its Rust bindings, so `runtime` resolves its `xla::` paths to this module
//! unless the `xla-runtime` feature is enabled (see `runtime/mod.rs`).
//!
//! [`Literal`] is fully functional — it is plain host memory, and the
//! `Input`/extraction plumbing in `runtime` is unit-tested against it.
//! Everything that would need a real PJRT client ([`PjRtClient::cpu`],
//! compilation, execution) returns [`XlaError::Unavailable`], which callers
//! surface as "artifacts runtime unavailable" and tests treat as a skip.

use thiserror::Error;

#[derive(Debug, Error, PartialEq, Eq)]
pub enum XlaError {
    #[error("PJRT runtime unavailable: {0} (rebuild with `--features xla-runtime` and the real `xla` crate)")]
    Unavailable(&'static str),
    #[error("cannot reshape {count} elements to {dims:?}")]
    Shape { count: usize, dims: Vec<i64> },
    #[error("literal element type mismatch")]
    ElementType,
}

/// Typed storage behind a [`Literal`].
#[derive(Debug, Clone)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

/// Element types a [`Literal`] can hold.
pub trait Element: Copy {
    fn into_data(v: Vec<Self>) -> LiteralData;
    fn from_data(d: &LiteralData) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn into_data(v: Vec<Self>) -> LiteralData {
        LiteralData::F32(v)
    }
    fn from_data(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn into_data(v: Vec<Self>) -> LiteralData {
        LiteralData::I32(v)
    }
    fn from_data(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Element for u32 {
    fn into_data(v: Vec<Self>) -> LiteralData {
        LiteralData::U32(v)
    }
    fn from_data(d: &LiteralData) -> Option<Vec<Self>> {
        match d {
            LiteralData::U32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Host-memory literal mirroring `xla::Literal`'s API surface used by
/// `runtime`.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: Element>(data: &[T]) -> Literal {
        let n = data.len() as i64;
        Literal { data: T::into_data(data.to_vec()), dims: vec![n] }
    }

    /// Rank-0 f32 literal.
    pub fn scalar(v: f32) -> Literal {
        Literal { data: LiteralData::F32(vec![v]), dims: Vec::new() }
    }

    /// Reinterpret the element buffer under new dimensions. Every dimension
    /// must be non-negative and their product must equal the element count
    /// (overflow-checked), mirroring real XLA's validation.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal, XlaError> {
        let count = self.element_count();
        let want = dims.iter().try_fold(1i64, |acc, &d| {
            if d < 0 {
                None
            } else {
                acc.checked_mul(d)
            }
        });
        match want {
            Some(w) if w as usize == count => {
                Ok(Literal { data: self.data, dims: dims.to_vec() })
            }
            _ => Err(XlaError::Shape { count, dims: dims.to_vec() }),
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::U32(v) => v.len(),
        }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Extract the elements, checking the stored type.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>, XlaError> {
        T::from_data(&self.data).ok_or(XlaError::ElementType)
    }

    /// Decompose a tuple literal. The stub never produces tuples (they only
    /// come out of executions, which need a real client).
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        Err(XlaError::Unavailable("tuple literals come from PJRT executions"))
    }
}

/// Parsed HLO module handle (inert in the stub).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        Err(XlaError::Unavailable("HLO parsing needs the XLA library"))
    }
}

/// Computation handle (inert in the stub).
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (inert in the stub).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Err(XlaError::Unavailable("no device buffers without a PJRT client"))
    }
}

/// Compiled executable handle (inert in the stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(XlaError::Unavailable("execution needs the XLA library"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] always fails in the stub, so the
/// inert handles above are unreachable in practice.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Err(XlaError::Unavailable("built without the `xla-runtime` feature"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        Err(XlaError::Unavailable("compilation needs the XLA library"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(r.to_vec::<i32>(), Err(XlaError::ElementType));
        assert!(Literal::vec1(&[1u32, 2]).reshape(&[3]).is_err());
        // negative dims must be rejected even when their product matches
        assert!(Literal::vec1(&[1.0f32; 4]).reshape(&[-2, -2]).is_err());
    }

    #[test]
    fn scalar_is_rank_zero() {
        let s = Literal::scalar(7.5);
        assert_eq!(s.element_count(), 1);
        assert!(s.dims().is_empty());
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.5]);
    }

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla-runtime"));
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
    }
}
