//! Softmax-regression (face model) runtime: wraps the `softreg_train`,
//! `softreg_predict` and `inversion` HLO executables. This is the model
//! of the paper's privacy experiments (Fig 2 / A.4, Tables 5.2 / A.3),
//! matching the Fredrikson et al. model-inversion setting.

use super::{scalar_f32, to_f32, FaceDims, HloExecutable, Input, Runtime};
use anyhow::{bail, Result};

/// Softmax-regression parameters (w: d×c row-major, b: c).
#[derive(Debug, Clone, PartialEq)]
pub struct SoftregParams {
    pub dims: FaceDims,
    pub w: Vec<f32>,
    pub b: Vec<f32>,
}

impl SoftregParams {
    pub fn zeros(dims: FaceDims) -> SoftregParams {
        SoftregParams { dims, w: vec![0.0; dims.d * dims.c], b: vec![0.0; dims.c] }
    }

    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.dims.param_count());
        out.extend_from_slice(&self.w);
        out.extend_from_slice(&self.b);
        out
    }

    pub fn from_flat(dims: FaceDims, flat: &[f32]) -> Result<SoftregParams> {
        if flat.len() != dims.param_count() {
            bail!("flat length {} != param count {}", flat.len(), dims.param_count());
        }
        let (w, b) = flat.split_at(dims.d * dims.c);
        Ok(SoftregParams { dims, w: w.to_vec(), b: b.to_vec() })
    }
}

pub struct SoftregRuntime {
    pub dims: FaceDims,
    train: HloExecutable,
    predict: HloExecutable,
    inversion: HloExecutable,
}

impl SoftregRuntime {
    pub fn load(rt: &Runtime) -> Result<SoftregRuntime> {
        Ok(SoftregRuntime {
            dims: rt.manifest.face_dims(),
            train: rt.load("softreg_train")?,
            predict: rt.load("softreg_predict")?,
            inversion: rt.load("inversion")?,
        })
    }

    /// One SGD step; updates `p` in place, returns the loss.
    pub fn train_step(
        &self,
        p: &mut SoftregParams,
        x: &[f32],
        y_onehot: &[f32],
        lr: f32,
    ) -> Result<f32> {
        let d = self.dims;
        if x.len() != d.batch * d.d || y_onehot.len() != d.batch * d.c {
            bail!("softreg train batch shape mismatch");
        }
        let inputs = vec![
            Input::F32(p.w.clone(), vec![d.d as i64, d.c as i64]),
            Input::F32(p.b.clone(), vec![d.c as i64]),
            Input::F32(x.to_vec(), vec![d.batch as i64, d.d as i64]),
            Input::F32(y_onehot.to_vec(), vec![d.batch as i64, d.c as i64]),
            Input::ScalarF32(lr),
        ];
        let outs = self.train.run(&inputs)?;
        p.w = to_f32(&outs[0])?;
        p.b = to_f32(&outs[1])?;
        scalar_f32(&outs[2])
    }

    /// Class probabilities for one batch (batch·c, row-major).
    pub fn predict(&self, p: &SoftregParams, x: &[f32]) -> Result<Vec<f32>> {
        let d = self.dims;
        if x.len() != d.batch * d.d {
            bail!("predict batch shape mismatch");
        }
        let inputs = vec![
            Input::F32(p.w.clone(), vec![d.d as i64, d.c as i64]),
            Input::F32(p.b.clone(), vec![d.c as i64]),
            Input::F32(x.to_vec(), vec![d.batch as i64, d.d as i64]),
        ];
        let outs = self.predict.run(&inputs)?;
        to_f32(&outs[0])
    }

    /// One model-inversion gradient step on the input image (batch=1).
    /// Returns (x', loss).
    pub fn inversion_step(
        &self,
        p: &SoftregParams,
        x: &[f32],
        target_onehot: &[f32],
        step_size: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let d = self.dims;
        if x.len() != d.d || target_onehot.len() != d.c {
            bail!("inversion shape mismatch");
        }
        let inputs = vec![
            Input::F32(p.w.clone(), vec![d.d as i64, d.c as i64]),
            Input::F32(p.b.clone(), vec![d.c as i64]),
            Input::F32(x.to_vec(), vec![1, d.d as i64]),
            Input::F32(target_onehot.to_vec(), vec![1, d.c as i64]),
            Input::ScalarF32(step_size),
        ];
        let outs = self.inversion.run(&inputs)?;
        Ok((to_f32(&outs[0])?, scalar_f32(&outs[1])?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> FaceDims {
        FaceDims { batch: 20, d: 1024, c: 40 }
    }

    #[test]
    fn flatten_round_trip() {
        let mut p = SoftregParams::zeros(dims());
        p.w[17] = 3.25;
        p.b[5] = -1.5;
        let q = SoftregParams::from_flat(dims(), &p.flatten()).unwrap();
        assert_eq!(p, q);
        assert!(SoftregParams::from_flat(dims(), &[0.0; 3]).is_err());
    }
}
