//! PJRT runtime: load the AOT HLO-text artifacts and execute them from
//! Rust. Python never runs on this path.
//!
//! The flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts are described by `artifacts/manifest.json` (shapes, dtypes,
//! output arity), emitted by `python/compile/aot.py`.

pub mod mlp;
pub mod softreg;

// The `xla::` paths below resolve to the real PJRT bindings only when the
// `xla-runtime` feature is enabled (the `xla` crate dependency must then be
// added to Cargo.toml); the hermetic default build routes them to the
// in-tree stub, whose client constructor reports the runtime unavailable.
#[cfg(not(feature = "xla-runtime"))]
#[path = "xla_stub.rs"]
pub mod xla;

// Fail fast with instructions instead of a wall of unresolved `xla::` paths
// when the feature is flipped on without wiring up the dependency.
#[cfg(feature = "xla-runtime")]
compile_error!(
    "the `xla-runtime` feature needs the real PJRT bindings: add the `xla` \
     crate to rust/Cargo.toml's [dependencies] and delete this guard \
     (runtime/mod.rs); the default build uses the in-tree stub instead"
);

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Typed input value for an HLO executable.
#[derive(Debug, Clone)]
pub enum Input {
    F32(Vec<f32>, Vec<i64>),
    I32(Vec<i32>, Vec<i64>),
    U32(Vec<u32>, Vec<i64>),
    ScalarF32(f32),
}

impl Input {
    fn to_literal(&self) -> Result<xla::Literal> {
        Ok(match self {
            Input::F32(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
            Input::I32(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
            Input::U32(data, shape) => xla::Literal::vec1(data).reshape(shape)?,
            Input::ScalarF32(v) => xla::Literal::scalar(*v),
        })
    }
}

/// The parsed artifact manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    json: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        if json.get("format").as_str() != Some("hlo-text/v1") {
            bail!("unsupported manifest format");
        }
        Ok(Manifest { dir: dir.to_path_buf(), json })
    }

    /// Default artifact directory: $CCESA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var("CCESA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn mlp_dims(&self) -> MlpDims {
        let m = self.json.get("mlp");
        MlpDims {
            batch: m.get("batch").as_usize().unwrap_or(32),
            d: m.get("d").as_usize().unwrap_or(192),
            h: m.get("h").as_usize().unwrap_or(256),
            c: m.get("c").as_usize().unwrap_or(10),
        }
    }

    pub fn face_dims(&self) -> FaceDims {
        let f = self.json.get("face");
        FaceDims {
            batch: f.get("batch").as_usize().unwrap_or(20),
            d: f.get("d").as_usize().unwrap_or(1024),
            c: f.get("c").as_usize().unwrap_or(40),
        }
    }

    pub fn agg_dims(&self) -> (usize, usize) {
        let a = self.json.get("agg");
        (
            a.get("clients").as_usize().unwrap_or(64),
            a.get("m").as_usize().unwrap_or(65536),
        )
    }

    fn artifact_file(&self, name: &str) -> Result<PathBuf> {
        let file = self
            .json
            .at(&["artifacts", name, "file"])
            .as_str()
            .ok_or_else(|| anyhow!("artifact {name} missing from manifest"))?;
        Ok(self.dir.join(file))
    }

    fn num_outputs(&self, name: &str) -> usize {
        self.json
            .at(&["artifacts", name, "num_outputs"])
            .as_usize()
            .unwrap_or(1)
    }
}

/// MLP AOT dimensions (fixed at lowering time).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MlpDims {
    pub batch: usize,
    pub d: usize,
    pub h: usize,
    pub c: usize,
}

impl MlpDims {
    pub fn param_count(&self) -> usize {
        self.d * self.h + self.h + self.h * self.c + self.c
    }
}

/// Face-model AOT dimensions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaceDims {
    pub batch: usize,
    pub d: usize,
    pub c: usize,
}

impl FaceDims {
    pub fn param_count(&self) -> usize {
        self.d * self.c + self.c
    }
}

/// A compiled HLO executable plus its output arity.
pub struct HloExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub num_outputs: usize,
}

impl HloExecutable {
    /// Execute with typed inputs; returns the decomposed output tuple.
    pub fn run(&self, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        let lits: Vec<xla::Literal> =
            inputs.iter().map(|i| i.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True
        let outs = result.to_tuple()?;
        if outs.len() != self.num_outputs {
            bail!(
                "{}: expected {} outputs, got {}",
                self.name,
                self.num_outputs,
                outs.len()
            );
        }
        Ok(outs)
    }
}

/// Output extraction helpers.
pub fn to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}
pub fn to_i32(lit: &xla::Literal) -> Result<Vec<i32>> {
    Ok(lit.to_vec::<i32>()?)
}
pub fn to_u32(lit: &xla::Literal) -> Result<Vec<u32>> {
    Ok(lit.to_vec::<u32>()?)
}
pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit.to_vec::<f32>()?;
    v.first().copied().ok_or_else(|| anyhow!("empty literal"))
}

/// The PJRT runtime: one CPU client plus the manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
}

impl Runtime {
    /// Create a CPU PJRT client and load the manifest from `dir`.
    pub fn cpu(dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest })
    }

    /// Create from the default artifact directory.
    pub fn cpu_default() -> Result<Runtime> {
        Self::cpu(&Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact.
    pub fn load(&self, name: &str) -> Result<HloExecutable> {
        let path = self.manifest.artifact_file(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(HloExecutable {
            exe,
            name: name.to_string(),
            num_outputs: self.manifest.num_outputs(name),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_when_artifacts_exist() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let dims = m.mlp_dims();
        assert!(dims.d > 0 && dims.h > 0 && dims.c > 1);
        assert!(dims.param_count() > 1000);
        assert!(m.artifact_file("mlp_train").unwrap().exists());
        assert_eq!(m.num_outputs("mlp_train"), 5);
    }

    #[test]
    fn input_literal_shapes() {
        let i = Input::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        let lit = i.to_literal().unwrap();
        assert_eq!(lit.element_count(), 4);
        let s = Input::ScalarF32(7.5).to_literal().unwrap();
        assert_eq!(s.element_count(), 1);
        let u = Input::U32(vec![1, 2, 3], vec![3]).to_literal().unwrap();
        assert_eq!(u.to_vec::<u32>().unwrap(), vec![1, 2, 3]);
    }
}
