//! HMAC-SHA256 (RFC 2104), used by HKDF.

use super::sha256::Sha256;

/// One-shot HMAC-SHA256.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        let d = {
            let mut h = Sha256::new();
            h.update(key);
            h.finalize()
        };
        k[..32].copy_from_slice(&d);
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0x36u8; 64];
    let mut opad = [0x5cu8; 64];
    for i in 0..64 {
        ipad[i] ^= k[i];
        opad[i] ^= k[i];
    }
    let inner = {
        let mut h = Sha256::new();
        h.update(&ipad).update(msg);
        h.finalize()
    };
    let mut h = Sha256::new();
    h.update(&opad).update(&inner);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 4231 test cases.
    #[test]
    fn rfc4231_case1() {
        let key = [0x0b; 20];
        let out = hmac_sha256(&key, b"Hi There");
        assert_eq!(
            hex::encode(&out),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn rfc4231_case2() {
        let out = hmac_sha256(b"Jefe", b"what do ya want for nothing?");
        assert_eq!(
            hex::encode(&out),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn rfc4231_case6_long_key() {
        let key = [0xaa; 131];
        let out = hmac_sha256(&key, b"Test Using Larger Than Block-Size Key - Hash Key First");
        assert_eq!(
            hex::encode(&out),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn cross_check_hmac_crate() {
        use hmac::{Hmac, Mac};
        type H = Hmac<sha2::Sha256>;
        let mut rng = crate::util::rng::Rng::new(0xFEED);
        for (klen, mlen) in [(0usize, 0usize), (16, 100), (64, 64), (65, 1), (200, 1000)] {
            let mut key = vec![0u8; klen];
            let mut msg = vec![0u8; mlen];
            rng.fill_bytes(&mut key);
            rng.fill_bytes(&mut msg);
            let ours = hmac_sha256(&key, &msg);
            let mut mac = H::new_from_slice(&key).unwrap();
            mac.update(&msg);
            let theirs: [u8; 32] = mac.finalize().into_bytes().into();
            assert_eq!(ours, theirs, "klen={klen} mlen={mlen}");
        }
    }
}
