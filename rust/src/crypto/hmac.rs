//! HMAC-SHA256 (RFC 2104), used by HKDF.

use super::sha256::Sha256;

/// One-shot HMAC-SHA256.
pub fn hmac_sha256(key: &[u8], msg: &[u8]) -> [u8; 32] {
    let mut k = [0u8; 64];
    if key.len() > 64 {
        let d = {
            let mut h = Sha256::new();
            h.update(key);
            h.finalize()
        };
        k[..32].copy_from_slice(&d);
    } else {
        k[..key.len()].copy_from_slice(key);
    }
    let mut ipad = [0x36u8; 64];
    let mut opad = [0x5cu8; 64];
    for i in 0..64 {
        ipad[i] ^= k[i];
        opad[i] ^= k[i];
    }
    let inner = {
        let mut h = Sha256::new();
        h.update(&ipad).update(msg);
        h.finalize()
    };
    let mut h = Sha256::new();
    h.update(&opad).update(&inner);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 4231 test cases.
    #[test]
    fn rfc4231_case1() {
        let key = [0x0b; 20];
        let out = hmac_sha256(&key, b"Hi There");
        assert_eq!(
            hex::encode(&out),
            "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
        );
    }

    #[test]
    fn rfc4231_case2() {
        let out = hmac_sha256(b"Jefe", b"what do ya want for nothing?");
        assert_eq!(
            hex::encode(&out),
            "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
        );
    }

    #[test]
    fn rfc4231_case6_long_key() {
        let key = [0xaa; 131];
        let out = hmac_sha256(&key, b"Test Using Larger Than Block-Size Key - Hash Key First");
        assert_eq!(
            hex::encode(&out),
            "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
        );
    }

    #[test]
    fn rfc4231_case3_repeated_bytes() {
        let key = [0xaa; 20];
        let out = hmac_sha256(&key, &[0xdd; 50]);
        assert_eq!(
            hex::encode(&out),
            "773ea91e36800e46854db8ebd09181a72959098b3ef8c122d9635514ced565fe"
        );
    }

    #[test]
    fn rfc4231_case4_25_byte_key() {
        let key: Vec<u8> = (1..=25).collect();
        let out = hmac_sha256(&key, &[0xcd; 50]);
        assert_eq!(
            hex::encode(&out),
            "82558a389a443c0ea4cc819899f2083a85f0faa3e578f8077a2e3ff46729665b"
        );
    }

    #[test]
    fn rfc4231_case7_long_key_long_data() {
        let key = [0xaa; 131];
        let msg = b"This is a test using a larger than block-size key and a \
larger than block-size data. The key needs to be hashed before being used \
by the HMAC algorithm.";
        let out = hmac_sha256(&key, msg);
        assert_eq!(
            hex::encode(&out),
            "9b09ffa71b942fcb27635fbcd5b0e944bfdc63644f0713938a7f51535c3a35e2"
        );
    }
}
