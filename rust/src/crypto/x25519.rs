//! X25519 Diffie–Hellman (RFC 7748), from scratch.
//!
//! Field arithmetic over GF(2^255 − 19) with five 51-bit limbs in u64
//! (products accumulated in u128), and the constant-time Montgomery ladder.
//!
//! This is the key-agreement function `f` of the paper:
//! `s_{i,j} = f(s_j^PK, s_i^SK) = f(s_i^PK, s_j^SK)`.

/// Field element: five 51-bit limbs, little-endian.
#[derive(Clone, Copy, Debug)]
struct Fe([u64; 5]);

const MASK51: u64 = (1u64 << 51) - 1;

impl Fe {
    const ZERO: Fe = Fe([0; 5]);
    const ONE: Fe = Fe([1, 0, 0, 0, 0]);

    fn from_bytes(b: &[u8; 32]) -> Fe {
        let load = |i: usize| u64::from_le_bytes(b[i..i + 8].try_into().unwrap());
        Fe([
            load(0) & MASK51,
            (load(6) >> 3) & MASK51,
            (load(12) >> 6) & MASK51,
            (load(19) >> 1) & MASK51,
            (load(24) >> 12) & MASK51,
        ])
    }

    fn to_bytes(mut self) -> [u8; 32] {
        self = self.carry();
        self = self.carry();
        // reduce: add 19 and carry, then subtract 2^255 if set (freeze)
        let mut t = self.0;
        let mut q = (t[0].wrapping_add(19)) >> 51;
        q = (t[1] + q) >> 51;
        q = (t[2] + q) >> 51;
        q = (t[3] + q) >> 51;
        q = (t[4] + q) >> 51;
        t[0] += 19 * q;
        let mut c = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += c;
        c = t[1] >> 51;
        t[1] &= MASK51;
        t[2] += c;
        c = t[2] >> 51;
        t[2] &= MASK51;
        t[3] += c;
        c = t[3] >> 51;
        t[3] &= MASK51;
        t[4] += c;
        t[4] &= MASK51;

        let mut out = [0u8; 32];
        let lo = t[0] | (t[1] << 51);
        let mid = (t[1] >> 13) | (t[2] << 38);
        let hi = (t[2] >> 26) | (t[3] << 25);
        let top = (t[3] >> 39) | (t[4] << 12);
        out[0..8].copy_from_slice(&lo.to_le_bytes());
        out[8..16].copy_from_slice(&mid.to_le_bytes());
        out[16..24].copy_from_slice(&hi.to_le_bytes());
        out[24..32].copy_from_slice(&top.to_le_bytes());
        out
    }

    #[inline]
    fn carry(self) -> Fe {
        let mut t = self.0;
        let mut c: u64;
        c = t[0] >> 51;
        t[0] &= MASK51;
        t[1] += c;
        c = t[1] >> 51;
        t[1] &= MASK51;
        t[2] += c;
        c = t[2] >> 51;
        t[2] &= MASK51;
        t[3] += c;
        c = t[3] >> 51;
        t[3] &= MASK51;
        t[4] += c;
        c = t[4] >> 51;
        t[4] &= MASK51;
        t[0] += c * 19;
        Fe(t)
    }

    #[inline]
    fn add(self, rhs: Fe) -> Fe {
        Fe([
            self.0[0] + rhs.0[0],
            self.0[1] + rhs.0[1],
            self.0[2] + rhs.0[2],
            self.0[3] + rhs.0[3],
            self.0[4] + rhs.0[4],
        ])
        .carry()
    }

    /// a - b, with bias 2p added to keep limbs positive.
    #[inline]
    fn sub(self, rhs: Fe) -> Fe {
        // 2p in 51-bit limbs: 2*(2^255-19) = (2^52-38, 2^52-2, ...)
        const TWO_P0: u64 = 0xFFFFFFFFFFFDA << 1;
        const TWO_P1234: u64 = 0xFFFFFFFFFFFFE << 1;
        Fe([
            self.0[0] + TWO_P0 - rhs.0[0],
            self.0[1] + TWO_P1234 - rhs.0[1],
            self.0[2] + TWO_P1234 - rhs.0[2],
            self.0[3] + TWO_P1234 - rhs.0[3],
            self.0[4] + TWO_P1234 - rhs.0[4],
        ])
        .carry()
    }

    #[inline]
    fn mul(self, rhs: Fe) -> Fe {
        let [a0, a1, a2, a3, a4] = self.0;
        let [b0, b1, b2, b3, b4] = rhs.0;
        let m = |x: u64, y: u64| x as u128 * y as u128;

        let mut r0 = m(a0, b0) + 19 * (m(a1, b4) + m(a2, b3) + m(a3, b2) + m(a4, b1));
        let mut r1 = m(a0, b1) + m(a1, b0) + 19 * (m(a2, b4) + m(a3, b3) + m(a4, b2));
        let mut r2 = m(a0, b2) + m(a1, b1) + m(a2, b0) + 19 * (m(a3, b4) + m(a4, b3));
        let mut r3 = m(a0, b3) + m(a1, b2) + m(a2, b1) + m(a3, b0) + 19 * m(a4, b4);
        let mut r4 = m(a0, b4) + m(a1, b3) + m(a2, b2) + m(a3, b1) + m(a4, b0);

        // carry chain over u128
        let mut c: u128;
        c = r0 >> 51;
        r0 &= MASK51 as u128;
        r1 += c;
        c = r1 >> 51;
        r1 &= MASK51 as u128;
        r2 += c;
        c = r2 >> 51;
        r2 &= MASK51 as u128;
        r3 += c;
        c = r3 >> 51;
        r3 &= MASK51 as u128;
        r4 += c;
        c = r4 >> 51;
        r4 &= MASK51 as u128;
        r0 += c * 19;
        // one more carry step leaves the element partially reduced
        // (limbs ≤ 2^51 + 2^13), which is safe to feed into further
        // mul/square/add calls — the full carry() pass is redundant (§Perf)
        c = r0 >> 51;
        r0 &= MASK51 as u128;
        r1 += c;

        Fe([r0 as u64, r1 as u64, r2 as u64, r3 as u64, r4 as u64])
    }

    /// Dedicated squaring: 15 limb products instead of mul's 25 (§Perf —
    /// the Montgomery ladder is 4 squarings per bit).
    #[inline]
    fn square(self) -> Fe {
        let [a0, a1, a2, a3, a4] = self.0;
        let m = |x: u64, y: u64| x as u128 * y as u128;

        let mut r0 = m(a0, a0) + 38 * (m(a1, a4) + m(a2, a3));
        let mut r1 = 2 * m(a0, a1) + 38 * m(a2, a4) + 19 * m(a3, a3);
        let mut r2 = 2 * m(a0, a2) + m(a1, a1) + 38 * m(a3, a4);
        let mut r3 = 2 * (m(a0, a3) + m(a1, a2)) + 19 * m(a4, a4);
        let mut r4 = 2 * (m(a0, a4) + m(a1, a3)) + m(a2, a2);

        let mut c: u128;
        c = r0 >> 51;
        r0 &= MASK51 as u128;
        r1 += c;
        c = r1 >> 51;
        r1 &= MASK51 as u128;
        r2 += c;
        c = r2 >> 51;
        r2 &= MASK51 as u128;
        r3 += c;
        c = r3 >> 51;
        r3 &= MASK51 as u128;
        r4 += c;
        c = r4 >> 51;
        r4 &= MASK51 as u128;
        r0 += c * 19;
        c = r0 >> 51;
        r0 &= MASK51 as u128;
        r1 += c;

        Fe([r0 as u64, r1 as u64, r2 as u64, r3 as u64, r4 as u64])
    }

    /// Multiply by small constant (121666 for the ladder).
    #[inline]
    fn mul_small(self, k: u64) -> Fe {
        let mut r = [0u128; 5];
        for i in 0..5 {
            r[i] = self.0[i] as u128 * k as u128;
        }
        let mut c: u128;
        let mut t = [0u64; 5];
        c = r[0] >> 51;
        t[0] = (r[0] as u64) & MASK51;
        r[1] += c;
        c = r[1] >> 51;
        t[1] = (r[1] as u64) & MASK51;
        r[2] += c;
        c = r[2] >> 51;
        t[2] = (r[2] as u64) & MASK51;
        r[3] += c;
        c = r[3] >> 51;
        t[3] = (r[3] as u64) & MASK51;
        r[4] += c;
        c = r[4] >> 51;
        t[4] = (r[4] as u64) & MASK51;
        t[0] += (c as u64) * 19;
        Fe(t).carry()
    }

    /// Inversion via Fermat: a^(p-2).
    fn invert(self) -> Fe {
        // addition chain from curve25519 reference
        let z = self;
        let z2 = z.square(); // 2
        let z9 = z2.square().square().mul(z); // 9
        let z11 = z9.mul(z2); // 11
        let z2_5_0 = z11.square().mul(z9); // 2^5 - 2^0 = 31
        let mut t = z2_5_0;
        for _ in 0..5 {
            t = t.square();
        }
        let z2_10_0 = t.mul(z2_5_0);
        t = z2_10_0;
        for _ in 0..10 {
            t = t.square();
        }
        let z2_20_0 = t.mul(z2_10_0);
        t = z2_20_0;
        for _ in 0..20 {
            t = t.square();
        }
        let z2_40_0 = t.mul(z2_20_0);
        t = z2_40_0;
        for _ in 0..10 {
            t = t.square();
        }
        let z2_50_0 = t.mul(z2_10_0);
        t = z2_50_0;
        for _ in 0..50 {
            t = t.square();
        }
        let z2_100_0 = t.mul(z2_50_0);
        t = z2_100_0;
        for _ in 0..100 {
            t = t.square();
        }
        let z2_200_0 = t.mul(z2_100_0);
        t = z2_200_0;
        for _ in 0..50 {
            t = t.square();
        }
        let z2_250_0 = t.mul(z2_50_0);
        t = z2_250_0;
        for _ in 0..5 {
            t = t.square();
        }
        t.mul(z11) // 2^255 - 21
    }

    /// Constant-time conditional swap.
    #[inline]
    fn cswap(swap: u64, a: &mut Fe, b: &mut Fe) {
        let mask = 0u64.wrapping_sub(swap);
        for i in 0..5 {
            let x = mask & (a.0[i] ^ b.0[i]);
            a.0[i] ^= x;
            b.0[i] ^= x;
        }
    }
}

/// Clamp a 32-byte scalar per RFC 7748.
pub fn clamp_scalar(mut k: [u8; 32]) -> [u8; 32] {
    k[0] &= 248;
    k[31] &= 127;
    k[31] |= 64;
    k
}

/// X25519 scalar multiplication: `k` (clamped internally) times point `u`.
pub fn x25519(k: &[u8; 32], u: &[u8; 32]) -> [u8; 32] {
    let k = clamp_scalar(*k);
    // mask top bit of u per RFC 7748
    let mut u = *u;
    u[31] &= 127;
    let x1 = Fe::from_bytes(&u);

    let mut x2 = Fe::ONE;
    let mut z2 = Fe::ZERO;
    let mut x3 = x1;
    let mut z3 = Fe::ONE;
    let mut swap = 0u64;

    for t in (0..255).rev() {
        let k_t = ((k[t / 8] >> (t % 8)) & 1) as u64;
        swap ^= k_t;
        Fe::cswap(swap, &mut x2, &mut x3);
        Fe::cswap(swap, &mut z2, &mut z3);
        swap = k_t;

        let a = x2.add(z2);
        let aa = a.square();
        let b = x2.sub(z2);
        let bb = b.square();
        let e = aa.sub(bb);
        let c = x3.add(z3);
        let d = x3.sub(z3);
        let da = d.mul(a);
        let cb = c.mul(b);
        x3 = da.add(cb).square();
        z3 = x1.mul(da.sub(cb).square());
        x2 = aa.mul(bb);
        z2 = e.mul(aa.add(e.mul_small(121665)));
    }
    Fe::cswap(swap, &mut x2, &mut x3);
    Fe::cswap(swap, &mut z2, &mut z3);

    x2.mul(z2.invert()).to_bytes()
}

/// The X25519 base point (u = 9).
pub const BASEPOINT: [u8; 32] = {
    let mut b = [0u8; 32];
    b[0] = 9;
    b
};

/// Derive the public key for a secret scalar.
pub fn public_key(secret: &[u8; 32]) -> [u8; 32] {
    x25519(secret, &BASEPOINT)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 7748 §5.2 test vector 1.
    #[test]
    fn rfc7748_vector1() {
        let k = hex::decode_array::<32>(
            "a546e36bf0527c9d3b16154b82465edd62144c0ac1fc5a18506a2244ba449ac4",
        )
        .unwrap();
        let u = hex::decode_array::<32>(
            "e6db6867583030db3594c1a424b15f7c726624ec26b3353b10a903a6d0ab1c4c",
        )
        .unwrap();
        let out = x25519(&k, &u);
        assert_eq!(
            hex::encode(&out),
            "c3da55379de9c6908e94ea4df28d084f32eccf03491c71f754b4075577a28552"
        );
    }

    // RFC 7748 §5.2 test vector 2 (u has high bit set — must be masked).
    #[test]
    fn rfc7748_vector2() {
        let k = hex::decode_array::<32>(
            "4b66e9d4d1b4673c5ad22691957d6af5c11b6421e0ea01d42ca4169e7918ba0d",
        )
        .unwrap();
        let u = hex::decode_array::<32>(
            "e5210f12786811d3f4b7959d0538ae2c31dbe7106fc03c3efc4cd549c715a493",
        )
        .unwrap();
        let out = x25519(&k, &u);
        assert_eq!(
            hex::encode(&out),
            "95cbde9476e8907d7aade45cb4b873f88b595a68799fa152e6f8f7647aac7957"
        );
    }

    // RFC 7748 §5.2 iteration test (1 and 1,000 iterations).
    #[test]
    fn rfc7748_iterated() {
        let mut k = BASEPOINT;
        let mut u = BASEPOINT;
        let out1 = x25519(&k, &u);
        // after 1 iteration
        assert_eq!(
            hex::encode(&out1),
            "422c8e7a6227d7bca1350b3e2bb7279f7897b87bb6854b783c60e80311ae3079"
        );
        u = k;
        k = out1;
        for _ in 1..1000 {
            let r = x25519(&k, &u);
            u = k;
            k = r;
        }
        assert_eq!(
            hex::encode(&k),
            "684cf59ba83309552800ef566f2f4d3c1c3887c49360e3875f2eb94d99532c51"
        );
    }

    // RFC 7748 §6.1 Diffie-Hellman vector.
    #[test]
    fn rfc7748_dh() {
        let alice_sk = hex::decode_array::<32>(
            "77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a",
        )
        .unwrap();
        let bob_sk = hex::decode_array::<32>(
            "5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb",
        )
        .unwrap();
        let alice_pk = public_key(&alice_sk);
        let bob_pk = public_key(&bob_sk);
        assert_eq!(
            hex::encode(&alice_pk),
            "8520f0098930a754748b7ddcb43ef75a0dbf3a0d26381af4eba4a98eaa9b4e6a"
        );
        assert_eq!(
            hex::encode(&bob_pk),
            "de9edb7d7b7dc1b4d35b61c2ece435373f8343c85b78674dadfc7e146f882b4f"
        );
        let k1 = x25519(&alice_sk, &bob_pk);
        let k2 = x25519(&bob_sk, &alice_pk);
        assert_eq!(k1, k2);
        assert_eq!(
            hex::encode(&k1),
            "4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742"
        );
    }

    #[test]
    fn dh_symmetry_random_keys() {
        let mut rng = crate::util::rng::Rng::new(0x715519);
        for _ in 0..8 {
            let mut a = [0u8; 32];
            let mut b = [0u8; 32];
            rng.fill_bytes(&mut a);
            rng.fill_bytes(&mut b);
            let pa = public_key(&a);
            let pb = public_key(&b);
            assert_eq!(x25519(&a, &pb), x25519(&b, &pa));
        }
    }

    #[test]
    fn clamping_applied() {
        let k = [0xFFu8; 32];
        let c = clamp_scalar(k);
        assert_eq!(c[0] & 7, 0);
        assert_eq!(c[31] & 0x80, 0);
        assert_eq!(c[31] & 0x40, 0x40);
    }
}
