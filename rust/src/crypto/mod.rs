//! Cryptographic substrates, implemented from scratch.
//!
//! The CCESA protocol (Algorithm 1 of the paper) needs four primitives:
//!
//! 1. **Key agreement** `f(PK_j, SK_i) = f(PK_i, SK_j)` — [`x25519`]
//!    (RFC 7748) with an HKDF-SHA256 KDF ([`dh`]). The paper used ECDH over
//!    NIST SP800-56 + SHA-256; x25519 plays the identical role (see
//!    DESIGN.md substitution table).
//! 2. **Symmetric authenticated encryption** of secret shares —
//!    [`aead`] ChaCha20-Poly1305 (RFC 8439) standing in for AES-GCM-128.
//! 3. **PRG** expanding a 32-byte seed into a mask vector over Z_{2^b} —
//!    [`prg`] (ChaCha20 keystream).
//! 4. **t-out-of-n secret sharing** — lives in [`crate::shamir`] over
//!    GF(2^16) (supports n up to 65534, needed for the n=1000 experiments).
//!
//! Every primitive is validated against RFC/NIST test vectors, both in unit
//! tests here and through the public API in `rust/tests/crypto_vectors.rs`
//! (the golden-vector suite), keeping the crate free of external crypto
//! dependencies.

pub mod aead;
pub mod chacha20;
pub mod dh;
pub mod hkdf;
pub mod hmac;
pub mod poly1305;
pub mod prg;
pub mod sha256;
pub mod x25519;
