//! Diffie–Hellman key pairs and the paper's key-agreement function `f`.
//!
//! Each client holds two pairs (Algorithm 1):
//!  * `(c_i^PK, c_i^SK)` — for the AEAD keys `c_{i,j}` encrypting shares;
//!  * `(s_i^PK, s_i^SK)` — for the pairwise mask seeds `s_{i,j}`.
//!
//! `agree_*` = x25519(SK_i, PK_j) passed through HKDF-SHA256 with a
//! purpose-specific info string, so mask seeds and encryption keys are
//! independent even for the same key pair.

use super::hkdf::hkdf32;
use super::x25519::{clamp_scalar, public_key, x25519};
use crate::util::rng::Rng;

pub type PublicKey = [u8; 32];
pub type SecretKey = [u8; 32];

/// An x25519 key pair.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KeyPair {
    pub pk: PublicKey,
    pub sk: SecretKey,
}

impl KeyPair {
    /// Generate from the (deterministic, seeded) simulation RNG.
    pub fn generate(rng: &mut Rng) -> KeyPair {
        let mut sk = [0u8; 32];
        rng.fill_bytes(&mut sk);
        sk = clamp_scalar(sk);
        KeyPair { pk: public_key(&sk), sk }
    }

    /// Rebuild a key pair from a secret key (e.g. a Shamir-reconstructed
    /// `s_i^SK` at the server in Step 3).
    pub fn from_secret(sk: SecretKey) -> KeyPair {
        let sk = clamp_scalar(sk);
        KeyPair { pk: public_key(&sk), sk }
    }
}

/// Raw shared point (used when the caller applies its own KDF).
pub fn shared_point(sk: &SecretKey, pk: &PublicKey) -> [u8; 32] {
    x25519(sk, pk)
}

/// Key agreement for the pairwise *mask seed* `s_{i,j}`.
pub fn agree_mask_seed(sk: &SecretKey, pk: &PublicKey) -> [u8; 32] {
    hkdf32(b"ccesa/v1", &shared_point(sk, pk), b"mask-seed")
}

/// Key agreement for the pairwise *encryption key* `c_{i,j}`.
pub fn agree_enc_key(sk: &SecretKey, pk: &PublicKey) -> [u8; 32] {
    hkdf32(b"ccesa/v1", &shared_point(sk, pk), b"enc-key")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn agreement_is_symmetric() {
        let mut rng = Rng::new(0xD1FF1E);
        let a = KeyPair::generate(&mut rng);
        let b = KeyPair::generate(&mut rng);
        assert_eq!(agree_mask_seed(&a.sk, &b.pk), agree_mask_seed(&b.sk, &a.pk));
        assert_eq!(agree_enc_key(&a.sk, &b.pk), agree_enc_key(&b.sk, &a.pk));
    }

    #[test]
    fn mask_and_enc_keys_differ() {
        let mut rng = Rng::new(1);
        let a = KeyPair::generate(&mut rng);
        let b = KeyPair::generate(&mut rng);
        assert_ne!(agree_mask_seed(&a.sk, &b.pk), agree_enc_key(&a.sk, &b.pk));
    }

    #[test]
    fn distinct_pairs_distinct_secrets() {
        let mut rng = Rng::new(2);
        let a = KeyPair::generate(&mut rng);
        let b = KeyPair::generate(&mut rng);
        let c = KeyPair::generate(&mut rng);
        assert_ne!(agree_mask_seed(&a.sk, &b.pk), agree_mask_seed(&a.sk, &c.pk));
    }

    #[test]
    fn from_secret_recovers_public() {
        let mut rng = Rng::new(3);
        let kp = KeyPair::generate(&mut rng);
        let rebuilt = KeyPair::from_secret(kp.sk);
        assert_eq!(rebuilt.pk, kp.pk);
    }

    #[test]
    fn generation_is_deterministic_in_seed() {
        let k1 = KeyPair::generate(&mut Rng::new(42));
        let k2 = KeyPair::generate(&mut Rng::new(42));
        assert_eq!(k1, k2);
    }
}
