//! HKDF-SHA256 (RFC 5869): extract-and-expand KDF.
//!
//! The x25519 shared point is not uniformly distributed, so key agreement
//! output is always passed through HKDF before use as a mask seed or AEAD
//! key — mirroring the paper's "composed with a SHA-256 hash" construction.

use super::hmac::hmac_sha256;

/// HKDF-Extract: PRK = HMAC(salt, ikm).
pub fn extract(salt: &[u8], ikm: &[u8]) -> [u8; 32] {
    hmac_sha256(salt, ikm)
}

/// HKDF-Expand to `out.len()` bytes (≤ 255·32).
pub fn expand(prk: &[u8; 32], info: &[u8], out: &mut [u8]) {
    assert!(out.len() <= 255 * 32, "hkdf expand too long");
    let mut t: Vec<u8> = Vec::new();
    let mut written = 0;
    let mut counter = 1u8;
    while written < out.len() {
        let mut input = Vec::with_capacity(t.len() + info.len() + 1);
        input.extend_from_slice(&t);
        input.extend_from_slice(info);
        input.push(counter);
        let block = hmac_sha256(prk, &input);
        let n = (out.len() - written).min(32);
        out[written..written + n].copy_from_slice(&block[..n]);
        written += n;
        t = block.to_vec();
        counter = counter.wrapping_add(1);
    }
}

/// One-shot extract+expand to a 32-byte key.
pub fn hkdf32(salt: &[u8], ikm: &[u8], info: &[u8]) -> [u8; 32] {
    let prk = extract(salt, ikm);
    let mut out = [0u8; 32];
    expand(&prk, info, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 5869 Test Case 1.
    #[test]
    fn rfc5869_case1() {
        let ikm = [0x0b; 22];
        let salt = hex::decode("000102030405060708090a0b0c").unwrap();
        let info = hex::decode("f0f1f2f3f4f5f6f7f8f9").unwrap();
        let prk = extract(&salt, &ikm);
        assert_eq!(
            hex::encode(&prk),
            "077709362c2e32df0ddc3f0dc47bba6390b6c73bb50f9c3122ec844ad7c2b3e5"
        );
        let mut okm = [0u8; 42];
        expand(&prk, &info, &mut okm);
        assert_eq!(
            hex::encode(&okm),
            "3cb25f25faacd57a90434f64d0362f2a2d2d0a90cf1a5a4c5db02d56ecc4c5bf34007208d5b887185865"
        );
    }

    // RFC 5869 Test Case 3 (zero-length salt/info).
    #[test]
    fn rfc5869_case3() {
        let ikm = [0x0b; 22];
        let prk = extract(&[], &ikm);
        let mut okm = [0u8; 42];
        expand(&prk, &[], &mut okm);
        assert_eq!(
            hex::encode(&okm),
            "8da4e775a563c18f715f802a063c5a31b8a11f5c5ee1879ec3454e5f3c738d2d9d201395faa4b61a96c8"
        );
    }

    #[test]
    fn domain_separation() {
        let a = hkdf32(b"salt", b"ikm", b"mask");
        let b = hkdf32(b"salt", b"ikm", b"enc");
        assert_ne!(a, b);
        assert_eq!(a, hkdf32(b"salt", b"ikm", b"mask"));
    }

    #[test]
    fn expand_multiblock_prefix_consistency() {
        let prk = extract(b"s", b"k");
        let mut a = [0u8; 100];
        let mut b = [0u8; 32];
        expand(&prk, b"i", &mut a);
        expand(&prk, b"i", &mut b);
        assert_eq!(&a[..32], &b[..]);
    }
}
