//! The protocol pseudo-random generator `PRG(seed) → Z_{2^b}^m`.
//!
//! Expands a 32-byte seed into a vector of masked-domain words (Eq. (1)/(3)
//! of the paper). This is the Step-2 hot path: a client with degree d
//! expands d+1 mask vectors of length m (the model dimension).
//!
//! Implementation: ChaCha20 keystream consumed as little-endian u32 words
//! (or u64 pairs), truncated to the masking modulus 2^b. Domain-separated
//! nonces keep pairwise-mask streams distinct from self-mask streams.

use super::chacha20::ChaCha20;

/// Nonce for pairwise masks PRG(s_{i,j}).
pub const NONCE_PAIRWISE: [u8; 12] = *b"ccesa-pair\0\0";
/// Nonce for self masks PRG(b_i).
pub const NONCE_SELF: [u8; 12] = *b"ccesa-self\0\0";

/// Expand `seed` into `out.len()` u64 words, each reduced mod 2^bits.
///
/// `bits` ∈ [1, 64]. The masked aggregation domain is Z_{2^bits}; the
/// protocol default is 32 (training headroom), the Table 5.1 runtime bench
/// mirrors the paper's 2^16 field.
pub fn expand_masks(seed: &[u8; 32], nonce: &[u8; 12], bits: u32, out: &mut [u64]) {
    assert!((1..=64).contains(&bits), "mask width must be in 1..=64");
    let cipher = ChaCha20::new(seed, nonce);
    let modmask: u64 = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut counter = 0u32;
    if bits <= 32 {
        // one u32 of keystream per element; 16-block batches (§Perf)
        let mut quad = [0u32; 256];
        for chunk in out.chunks_mut(256) {
            cipher.block_words_x16(counter, &mut quad);
            counter = counter.wrapping_add(16);
            for (o, w) in chunk.iter_mut().zip(quad.iter()) {
                *o = *w as u64 & modmask;
            }
        }
    } else {
        let mut words = [0u32; 16];
        // two u32s per element
        for chunk in out.chunks_mut(8) {
            cipher.block_words(counter, &mut words);
            counter = counter.wrapping_add(1);
            for (k, o) in chunk.iter_mut().enumerate() {
                let lo = words[2 * k] as u64;
                let hi = words[2 * k + 1] as u64;
                *o = (lo | (hi << 32)) & modmask;
            }
        }
    }
}

/// Allocating convenience wrapper.
pub fn prg(seed: &[u8; 32], nonce: &[u8; 12], bits: u32, len: usize) -> Vec<u64> {
    let mut out = vec![0u64; len];
    expand_masks(seed, nonce, bits, &mut out);
    out
}

/// Add `PRG(seed)` into `acc` in place with sign `+1`/`-1` mod 2^bits,
/// without materializing the mask vector. This fused form is what Step 2
/// and the server's unmasking use after the perf pass — one pass over the
/// accumulator per mask, no temporary allocation.
pub fn apply_mask(
    acc: &mut [u64],
    seed: &[u8; 32],
    nonce: &[u8; 12],
    bits: u32,
    negate: bool,
) {
    assert!((1..=64).contains(&bits));
    let cipher = ChaCha20::new(seed, nonce);
    let modmask: u64 = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
    let mut counter = 0u32;
    if bits <= 32 {
        // §Perf: 8-block keystream batches (quarter rounds vectorize to
        // one AVX2/AVX-512 op per state word across blocks).
        let mut quad = [0u32; 256];
        let mut chunks = acc.chunks_exact_mut(256);
        for chunk in chunks.by_ref() {
            cipher.block_words_x16(counter, &mut quad);
            counter = counter.wrapping_add(16);
            if negate {
                for (a, w) in chunk.iter_mut().zip(quad.iter()) {
                    *a = a.wrapping_sub(*w as u64 & modmask) & modmask;
                }
            } else {
                for (a, w) in chunk.iter_mut().zip(quad.iter()) {
                    *a = a.wrapping_add(*w as u64 & modmask) & modmask;
                }
            }
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            cipher.block_words_x16(counter, &mut quad);
            for (a, w) in rem.iter_mut().zip(quad.iter()) {
                let m = *w as u64 & modmask;
                *a = if negate { a.wrapping_sub(m) } else { a.wrapping_add(m) } & modmask;
            }
        }
    } else {
        let mut words = [0u32; 16];
        for chunk in acc.chunks_mut(8) {
            cipher.block_words(counter, &mut words);
            counter = counter.wrapping_add(1);
            for (k, a) in chunk.iter_mut().enumerate() {
                let m = ((words[2 * k] as u64) | ((words[2 * k + 1] as u64) << 32)) & modmask;
                *a = if negate { a.wrapping_sub(m) } else { a.wrapping_add(m) } & modmask;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = prg(&[1u8; 32], &NONCE_SELF, 32, 100);
        let b = prg(&[1u8; 32], &NONCE_SELF, 32, 100);
        let c = prg(&[2u8; 32], &NONCE_SELF, 32, 100);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn nonce_domain_separation() {
        let a = prg(&[1u8; 32], &NONCE_SELF, 32, 64);
        let b = prg(&[1u8; 32], &NONCE_PAIRWISE, 32, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn respects_modulus() {
        for bits in [1u32, 8, 16, 31, 32, 33, 48, 64] {
            let v = prg(&[3u8; 32], &NONCE_SELF, bits, 257);
            if bits < 64 {
                assert!(v.iter().all(|&x| x < (1u64 << bits)), "bits={bits}");
            }
            // all-zero output would indicate a broken expansion
            assert!(v.iter().any(|&x| x != 0), "bits={bits}");
        }
    }

    #[test]
    fn prefix_stability() {
        // expanding to a longer length must agree on the common prefix
        let short = prg(&[9u8; 32], &NONCE_PAIRWISE, 32, 10);
        let long = prg(&[9u8; 32], &NONCE_PAIRWISE, 32, 1000);
        assert_eq!(&long[..10], &short[..]);
    }

    #[test]
    fn apply_mask_matches_expand_then_add() {
        for bits in [16u32, 32, 48] {
            let seed = [7u8; 32];
            let modulus_mask = (1u64 << bits) - 1;
            let base: Vec<u64> = (0..500u64).map(|i| (i * 977) & modulus_mask).collect();
            let mask = prg(&seed, &NONCE_PAIRWISE, bits, 500);

            let mut via_apply = base.clone();
            apply_mask(&mut via_apply, &seed, &NONCE_PAIRWISE, bits, false);
            let manual: Vec<u64> = base
                .iter()
                .zip(mask.iter())
                .map(|(b, m)| b.wrapping_add(*m) & modulus_mask)
                .collect();
            assert_eq!(via_apply, manual, "bits={bits}");

            // negation cancels
            apply_mask(&mut via_apply, &seed, &NONCE_PAIRWISE, bits, true);
            assert_eq!(via_apply, base, "bits={bits}");
        }
    }

    #[test]
    fn mask_distribution_roughly_uniform() {
        let v = prg(&[5u8; 32], &NONCE_SELF, 16, 20_000);
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let expect = (u16::MAX as f64) / 2.0;
        assert!((mean - expect).abs() < expect * 0.02, "mean={mean}");
    }
}
