//! The protocol pseudo-random generator `PRG(seed) → Z_{2^b}^m`.
//!
//! Expands a 32-byte seed into a vector of masked-domain words (Eq. (1)/(3)
//! of the paper). This is the Step-2 hot path: a client with degree d
//! expands d+1 mask vectors of length m (the model dimension).
//!
//! Implementation: ChaCha20 keystream consumed as little-endian u32 words
//! (or u64 pairs), truncated to the masking modulus 2^b. Domain-separated
//! nonces keep pairwise-mask streams distinct from self-mask streams.
//!
//! **Counter-seekability.** Element `e` of the mask vector consumes a fixed
//! keystream position — word `e` (b ≤ 32) or words `2e, 2e+1` (b > 32) —
//! so the stream can be entered mid-vector by seeking the ChaCha20 block
//! counter to `e / elems_per_block`. [`apply_mask_range`] and
//! [`expand_masks_at`] expose this: the mask pipeline shards one vector
//! across workers (`crate::par`), each regenerating exactly the keystream
//! range its slice consumes, with output bit-identical to the serial pass.
//! The serial [`apply_mask`] / [`expand_masks`] are the `start = 0` case of
//! the range APIs, so the two can never diverge.

use super::chacha20::{ChaCha20, BATCH_BLOCKS, WORDS_PER_BLOCK};
use crate::kernels::MaskStream;
use crate::util::mod_mask;

/// Nonce for pairwise masks PRG(s_{i,j}).
pub const NONCE_PAIRWISE: [u8; 12] = *b"ccesa-pair\0\0";
/// Nonce for self masks PRG(b_i).
pub const NONCE_SELF: [u8; 12] = *b"ccesa-self\0\0";
/// Nonce for the cross-round session seed ratchet ([`ratchet_seed`]).
pub const NONCE_RATCHET: [u8; 12] = *b"ccesa-rtch\0\0";
/// Nonce prefix (10 bytes + direction byte + zero) for warm-round share
/// transport ([`warm_share_pad`]).
pub const NONCE_WARM_SHARE_PREFIX: [u8; 10] = *b"ccesa-wshr";

/// Keystream words per vectorized batch (16 blocks × 16 words).
const BATCH_WORDS: usize = BATCH_BLOCKS * WORDS_PER_BLOCK;
/// Elements per block on the wide (b > 32) path: two u32 words each.
const WIDE_PER_BLOCK: usize = WORDS_PER_BLOCK / 2;

/// Per-round mask seed of a cross-round session: the first 32 keystream
/// bytes of `ChaCha20(base, NONCE_RATCHET)` at block counter `round`.
///
/// Counter-seekable by construction — deriving round k is O(1), not k
/// hash-chain steps — and one-way in the forward direction only in the
/// sense that distinct rounds use independent keystream blocks; the session
/// layer re-keys `base` itself whenever a secret key that could reconstruct
/// it has been revealed (see `protocol::session`).
pub fn ratchet_seed(base: &[u8; 32], round: u64) -> [u8; 32] {
    assert!(round <= u32::MAX as u64, "ratchet round {round} exceeds the u32 counter space");
    let cipher = ChaCha20::new(base, &NONCE_RATCHET);
    let mut block = [0u8; 64];
    cipher.block(round as u32, &mut block);
    block[..32].try_into().unwrap()
}

/// One-time pad for a warm-round share ciphertext: 32 keystream bytes of
/// `ChaCha20(enc_base, "ccesa-wshr" || dir || 0)` at block counter `round`.
///
/// Warm rounds re-deal only the fresh self-mask share `b_i^{(k)}_{j}` (32
/// bytes) over the cached pairwise channel key; the pad is XORed over the
/// share's byte encoding. `dir` separates the i→j and j→i streams that
/// share one `enc_base` (callers pass `(from < to) as u8`). Unlike the
/// cold-start AEAD path this carries no tag — a tampering server can only
/// corrupt the sum (already in its power by dropping messages), not learn
/// anything, and the differential harness catches corruption.
pub fn warm_share_pad(enc_base: &[u8; 32], dir: u8, round: u64) -> [u8; 32] {
    assert!(round <= u32::MAX as u64, "warm round {round} exceeds the u32 counter space");
    let mut nonce = [0u8; 12];
    nonce[..10].copy_from_slice(&NONCE_WARM_SHARE_PREFIX);
    nonce[10] = dir;
    let cipher = ChaCha20::new(enc_base, &nonce);
    let mut block = [0u8; 64];
    cipher.block(round as u32, &mut block);
    block[..32].try_into().unwrap()
}

/// Expand elements `start .. start + out.len()` of `PRG(seed)` into `out`,
/// each reduced mod 2^bits — `out` is a window of the conceptual full mask
/// vector. `expand_masks_at(seed, nonce, bits, 0, out)` is the classic
/// full-vector expansion; for any split point s,
/// `expand_masks_at(.., 0, &mut v[..s])` + `expand_masks_at(.., s, &mut
/// v[s..])` produces bit-identical `v`.
///
/// `bits` ∈ [1, 64] (see [`crate::util::mod_mask`]); the protocol default
/// is 32 (training headroom), the Table 5.1 runtime bench mirrors the
/// paper's 2^16 field.
pub fn expand_masks_at(
    seed: &[u8; 32],
    nonce: &[u8; 12],
    bits: u32,
    start: usize,
    out: &mut [u64],
) {
    let modmask = mod_mask(bits);
    let cipher = ChaCha20::new(seed, nonce);
    let len = out.len();
    if bits <= 32 {
        // one u32 of keystream per element; 16-block batches (§Perf)
        let mut batch = [0u32; BATCH_WORDS];
        let mut counter = (start / WORDS_PER_BLOCK) as u32;
        let mut skip = start % WORDS_PER_BLOCK;
        let mut pos = 0usize;
        while pos < len {
            cipher.block_words_x16(counter, &mut batch);
            counter = counter.wrapping_add(BATCH_BLOCKS as u32);
            let take = (BATCH_WORDS - skip).min(len - pos);
            for (o, w) in out[pos..pos + take].iter_mut().zip(batch[skip..skip + take].iter()) {
                *o = *w as u64 & modmask;
            }
            skip = 0;
            pos += take;
        }
    } else {
        // two u32s per element, one block per 8 elements
        let mut words = [0u32; WORDS_PER_BLOCK];
        let mut counter = (start / WIDE_PER_BLOCK) as u32;
        let mut skip = start % WIDE_PER_BLOCK;
        let mut pos = 0usize;
        while pos < len {
            cipher.block_words(counter, &mut words);
            counter = counter.wrapping_add(1);
            let take = (WIDE_PER_BLOCK - skip).min(len - pos);
            for (k, o) in out[pos..pos + take].iter_mut().enumerate() {
                let lo = words[2 * (skip + k)] as u64;
                let hi = words[2 * (skip + k) + 1] as u64;
                *o = (lo | (hi << 32)) & modmask;
            }
            skip = 0;
            pos += take;
        }
    }
}

/// Expand `seed` into `out.len()` u64 words, each reduced mod 2^bits —
/// the full-vector (`start = 0`) case of [`expand_masks_at`].
pub fn expand_masks(seed: &[u8; 32], nonce: &[u8; 12], bits: u32, out: &mut [u64]) {
    expand_masks_at(seed, nonce, bits, 0, out);
}

/// Allocating convenience wrapper.
pub fn prg(seed: &[u8; 32], nonce: &[u8; 12], bits: u32, len: usize) -> Vec<u64> {
    let mut out = vec![0u64; len];
    expand_masks(seed, nonce, bits, &mut out);
    out
}

/// Add elements `start .. start + acc.len()` of `PRG(seed)` into `acc` in
/// place with sign `+1`/`-1` mod 2^bits, without materializing the mask
/// vector. This fused, counter-seekable form is what Step 2 and the
/// server's unmasking use: `acc` is a disjoint shard of the accumulator,
/// `start` its offset in the full vector, and the worker seeks the ChaCha20
/// block counter to regenerate exactly the keystream range the shard
/// consumes. For any partition of the vector, composing the shards is
/// bit-identical to the serial `apply_mask` because Z_{2^b} addition is
/// elementwise and each element sees the same keystream word either way.
///
/// Implementation: the single-stream case of the keystream-major kernel
/// (`crate::kernels::apply_mask_stream`) — serial, sharded and multi-seed
/// application share one code path and can never diverge.
pub fn apply_mask_range(
    acc: &mut [u64],
    seed: &[u8; 32],
    nonce: &[u8; 12],
    bits: u32,
    negate: bool,
    start: usize,
) {
    crate::kernels::apply_mask_stream(acc, seed, nonce, bits, negate, start);
}

/// Add `PRG(seed)` into `acc` in place with sign `+1`/`-1` mod 2^bits —
/// the full-vector (`start = 0`) case of [`apply_mask_range`].
pub fn apply_mask(acc: &mut [u64], seed: &[u8; 32], nonce: &[u8; 12], bits: u32, negate: bool) {
    apply_mask_range(acc, seed, nonce, bits, negate, 0);
}

/// One planned mask application: a PRG stream (seed + domain-separating
/// nonce kind) added into the accumulator with a sign.
///
/// The plan-then-execute pipelines (client Step 2, server unmasking, the
/// aggregate bench) all express their mask work as a job list and replay
/// it per shard via [`apply_mask_jobs_range`] — one definition of the
/// nonce selection and sharding convention, so the bit-identity contract
/// cannot drift between call sites.
#[derive(Debug, Clone)]
pub struct MaskJob {
    pub seed: [u8; 32],
    /// Pairwise-mask stream ([`NONCE_PAIRWISE`]) vs self-mask
    /// ([`NONCE_SELF`]).
    pub pairwise: bool,
    pub negate: bool,
}

impl MaskJob {
    /// The domain-separating nonce this job's stream expands under.
    #[inline]
    pub fn nonce(&self) -> &'static [u8; 12] {
        if self.pairwise {
            &NONCE_PAIRWISE
        } else {
            &NONCE_SELF
        }
    }
}

/// Apply every job's keystream range to `acc`, a shard whose first element
/// is at `start` in the full vector. Composing shards over any partition is
/// bit-identical to applying all jobs serially over the whole vector.
///
/// §Perf: delegates to the fused multi-seed kernel
/// (`crate::kernels::apply_masks_fused`) — all jobs are expanded and
/// applied per ≤256-word accumulator block (keystream-major blocking), so
/// the shard is walked once instead of once per job, cutting accumulator
/// traffic ~(d+1)× for a degree-d client. Per element the same keystream
/// words are added with the same signs, so the result is bit-identical to
/// the one-pass-per-job form.
pub fn apply_mask_jobs_range(acc: &mut [u64], jobs: &[MaskJob], bits: u32, start: usize) {
    let streams: Vec<MaskStream> = jobs
        .iter()
        .map(|j| MaskStream { seed: j.seed, nonce: *j.nonce(), negate: j.negate })
        .collect();
    crate::kernels::apply_masks_fused(acc, &streams, bits, start);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let a = prg(&[1u8; 32], &NONCE_SELF, 32, 100);
        let b = prg(&[1u8; 32], &NONCE_SELF, 32, 100);
        let c = prg(&[2u8; 32], &NONCE_SELF, 32, 100);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn nonce_domain_separation() {
        let a = prg(&[1u8; 32], &NONCE_SELF, 32, 64);
        let b = prg(&[1u8; 32], &NONCE_PAIRWISE, 32, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn respects_modulus() {
        for bits in [1u32, 8, 16, 31, 32, 33, 48, 64] {
            let v = prg(&[3u8; 32], &NONCE_SELF, bits, 257);
            if bits < 64 {
                assert!(v.iter().all(|&x| x < (1u64 << bits)), "bits={bits}");
            }
            // all-zero output would indicate a broken expansion
            assert!(v.iter().any(|&x| x != 0), "bits={bits}");
        }
    }

    #[test]
    fn prefix_stability() {
        // expanding to a longer length must agree on the common prefix
        let short = prg(&[9u8; 32], &NONCE_PAIRWISE, 32, 10);
        let long = prg(&[9u8; 32], &NONCE_PAIRWISE, 32, 1000);
        assert_eq!(&long[..10], &short[..]);
    }

    #[test]
    fn apply_mask_matches_expand_then_add() {
        for bits in [16u32, 32, 48] {
            let seed = [7u8; 32];
            let modulus_mask = (1u64 << bits) - 1;
            let base: Vec<u64> = (0..500u64).map(|i| (i * 977) & modulus_mask).collect();
            let mask = prg(&seed, &NONCE_PAIRWISE, bits, 500);

            let mut via_apply = base.clone();
            apply_mask(&mut via_apply, &seed, &NONCE_PAIRWISE, bits, false);
            let manual: Vec<u64> = base
                .iter()
                .zip(mask.iter())
                .map(|(b, m)| b.wrapping_add(*m) & modulus_mask)
                .collect();
            assert_eq!(via_apply, manual, "bits={bits}");

            // negation cancels
            apply_mask(&mut via_apply, &seed, &NONCE_PAIRWISE, bits, true);
            assert_eq!(via_apply, base, "bits={bits}");
        }
    }

    #[test]
    fn expand_masks_at_window_equals_full_expansion() {
        // arbitrary windows of the stream equal the same slice of the full
        // vector, for both keystream layouts
        let seed = [0x5Eu8; 32];
        for bits in [16u32, 32, 48, 64] {
            let full = prg(&seed, &NONCE_SELF, bits, 1200);
            for (start, len) in
                [(0usize, 7usize), (1, 16), (15, 2), (255, 258), (256, 256), (511, 300), (1199, 1)]
            {
                let mut window = vec![0u64; len];
                expand_masks_at(&seed, &NONCE_SELF, bits, start, &mut window);
                assert_eq!(&window[..], &full[start..start + len], "bits={bits} start={start}");
            }
        }
    }

    #[test]
    fn apply_mask_range_composes_to_serial() {
        // splitting the accumulator at any point and applying the two
        // ranges equals one serial pass — the §Perf sharding invariant
        let seed = [0xA1u8; 32];
        for bits in [16u32, 32, 48, 64] {
            let modm = crate::util::mod_mask(bits);
            let base: Vec<u64> = (0..600u64).map(|i| (i * 2654435761) & modm).collect();
            let mut serial = base.clone();
            apply_mask(&mut serial, &seed, &NONCE_PAIRWISE, bits, false);
            for split in [0usize, 1, 16, 255, 256, 257, 512, 599, 600] {
                let mut sharded = base.clone();
                let (lo, hi) = sharded.split_at_mut(split);
                apply_mask_range(lo, &seed, &NONCE_PAIRWISE, bits, false, 0);
                apply_mask_range(hi, &seed, &NONCE_PAIRWISE, bits, false, split);
                assert_eq!(sharded, serial, "bits={bits} split={split}");
            }
        }
    }

    #[test]
    fn ratchet_rounds_are_independent_and_seekable() {
        let base = [0x11u8; 32];
        let s0 = ratchet_seed(&base, 0);
        let s1 = ratchet_seed(&base, 1);
        let s1000 = ratchet_seed(&base, 1000);
        assert_ne!(s0, s1);
        assert_ne!(s1, s1000);
        // deterministic: seeking straight to a round gives the same seed
        assert_eq!(ratchet_seed(&base, 1000), s1000);
        // base-sensitive
        assert_ne!(ratchet_seed(&[0x12u8; 32], 0), s0);
        // domain-separated from the mask expansion of the same key
        let mut direct = [0u64; 4];
        expand_masks(&base, &NONCE_SELF, 64, &mut direct);
        let s0_words: Vec<u64> =
            s0.chunks(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())).collect();
        assert_ne!(direct.to_vec(), s0_words);
    }

    #[test]
    fn warm_share_pad_separates_round_direction_and_key() {
        let k = [0x77u8; 32];
        let p = warm_share_pad(&k, 0, 3);
        assert_eq!(warm_share_pad(&k, 0, 3), p);
        assert_ne!(warm_share_pad(&k, 1, 3), p);
        assert_ne!(warm_share_pad(&k, 0, 4), p);
        assert_ne!(warm_share_pad(&[0x78u8; 32], 0, 3), p);
        // and from the ratchet stream of the same key
        assert_ne!(ratchet_seed(&k, 3), p);
    }

    #[test]
    fn mask_distribution_roughly_uniform() {
        let v = prg(&[5u8; 32], &NONCE_SELF, 16, 20_000);
        let mean = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let expect = (u16::MAX as f64) / 2.0;
        assert!((mean - expect).abs() < expect * 0.02, "mean={mean}");
    }
}
