//! Poly1305 one-time authenticator (RFC 8439 §2.5).

/// Compute the Poly1305 tag of `msg` under a 32-byte one-time key.
pub fn poly1305(key: &[u8; 32], msg: &[u8]) -> [u8; 16] {
    // r with clamping
    let mut r = [0u8; 16];
    r.copy_from_slice(&key[..16]);
    r[3] &= 15;
    r[7] &= 15;
    r[11] &= 15;
    r[15] &= 15;
    r[4] &= 252;
    r[8] &= 252;
    r[12] &= 252;

    // 26-bit limbs of r
    let r0 = (u32::from_le_bytes(r[0..4].try_into().unwrap())) & 0x3ffffff;
    let r1 = (u32::from_le_bytes(r[3..7].try_into().unwrap()) >> 2) & 0x3ffffff;
    let r2 = (u32::from_le_bytes(r[6..10].try_into().unwrap()) >> 4) & 0x3ffffff;
    let r3 = (u32::from_le_bytes(r[9..13].try_into().unwrap()) >> 6) & 0x3ffffff;
    let r4 = (u32::from_le_bytes(r[12..16].try_into().unwrap()) >> 8) & 0x3ffffff;

    let s1 = r1 * 5;
    let s2 = r2 * 5;
    let s3 = r3 * 5;
    let s4 = r4 * 5;

    let mut h0 = 0u32;
    let mut h1 = 0u32;
    let mut h2 = 0u32;
    let mut h3 = 0u32;
    let mut h4 = 0u32;

    let mut chunks = msg.chunks_exact(16);
    let mut process = |block: &[u8; 16], hibit: u32| {
        h0 = h0.wrapping_add(u32::from_le_bytes(block[0..4].try_into().unwrap()) & 0x3ffffff);
        h1 = h1.wrapping_add((u32::from_le_bytes(block[3..7].try_into().unwrap()) >> 2) & 0x3ffffff);
        h2 = h2.wrapping_add((u32::from_le_bytes(block[6..10].try_into().unwrap()) >> 4) & 0x3ffffff);
        h3 = h3.wrapping_add((u32::from_le_bytes(block[9..13].try_into().unwrap()) >> 6) & 0x3ffffff);
        h4 = h4.wrapping_add((u32::from_le_bytes(block[12..16].try_into().unwrap()) >> 8) | hibit);

        let d0 = (h0 as u64) * (r0 as u64)
            + (h1 as u64) * (s4 as u64)
            + (h2 as u64) * (s3 as u64)
            + (h3 as u64) * (s2 as u64)
            + (h4 as u64) * (s1 as u64);
        let mut d1 = (h0 as u64) * (r1 as u64)
            + (h1 as u64) * (r0 as u64)
            + (h2 as u64) * (s4 as u64)
            + (h3 as u64) * (s3 as u64)
            + (h4 as u64) * (s2 as u64);
        let mut d2 = (h0 as u64) * (r2 as u64)
            + (h1 as u64) * (r1 as u64)
            + (h2 as u64) * (r0 as u64)
            + (h3 as u64) * (s4 as u64)
            + (h4 as u64) * (s3 as u64);
        let mut d3 = (h0 as u64) * (r3 as u64)
            + (h1 as u64) * (r2 as u64)
            + (h2 as u64) * (r1 as u64)
            + (h3 as u64) * (r0 as u64)
            + (h4 as u64) * (s4 as u64);
        let mut d4 = (h0 as u64) * (r4 as u64)
            + (h1 as u64) * (r3 as u64)
            + (h2 as u64) * (r2 as u64)
            + (h3 as u64) * (r1 as u64)
            + (h4 as u64) * (r0 as u64);

        let mut c;
        c = d0 >> 26;
        h0 = (d0 & 0x3ffffff) as u32;
        d1 += c;
        c = d1 >> 26;
        h1 = (d1 & 0x3ffffff) as u32;
        d2 += c;
        c = d2 >> 26;
        h2 = (d2 & 0x3ffffff) as u32;
        d3 += c;
        c = d3 >> 26;
        h3 = (d3 & 0x3ffffff) as u32;
        d4 += c;
        c = d4 >> 26;
        h4 = (d4 & 0x3ffffff) as u32;
        h0 = h0.wrapping_add((c as u32) * 5);
        let c2 = h0 >> 26;
        h0 &= 0x3ffffff;
        h1 = h1.wrapping_add(c2);
    };

    for block in chunks.by_ref() {
        process(block.try_into().unwrap(), 1 << 24);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut block = [0u8; 16];
        block[..rem.len()].copy_from_slice(rem);
        block[rem.len()] = 1;
        process(&block, 0);
    }

    // full carry
    let mut c = h1 >> 26;
    h1 &= 0x3ffffff;
    h2 = h2.wrapping_add(c);
    c = h2 >> 26;
    h2 &= 0x3ffffff;
    h3 = h3.wrapping_add(c);
    c = h3 >> 26;
    h3 &= 0x3ffffff;
    h4 = h4.wrapping_add(c);
    c = h4 >> 26;
    h4 &= 0x3ffffff;
    h0 = h0.wrapping_add(c * 5);
    c = h0 >> 26;
    h0 &= 0x3ffffff;
    h1 = h1.wrapping_add(c);

    // compute h + -p
    let mut g0 = h0.wrapping_add(5);
    c = g0 >> 26;
    g0 &= 0x3ffffff;
    let mut g1 = h1.wrapping_add(c);
    c = g1 >> 26;
    g1 &= 0x3ffffff;
    let mut g2 = h2.wrapping_add(c);
    c = g2 >> 26;
    g2 &= 0x3ffffff;
    let mut g3 = h3.wrapping_add(c);
    c = g3 >> 26;
    g3 &= 0x3ffffff;
    let g4 = h4.wrapping_add(c).wrapping_sub(1 << 26);

    // select h if h < p, else h - p
    let mask = (g4 >> 31).wrapping_sub(1);
    g0 &= mask;
    g1 &= mask;
    g2 &= mask;
    g3 &= mask;
    let g4m = g4 & mask;
    let maskn = !mask;
    h0 = (h0 & maskn) | g0;
    h1 = (h1 & maskn) | g1;
    h2 = (h2 & maskn) | g2;
    h3 = (h3 & maskn) | g3;
    h4 = (h4 & maskn) | g4m;

    // serialize h mod 2^128
    let hh0 = h0 | (h1 << 26);
    let hh1 = (h1 >> 6) | (h2 << 20);
    let hh2 = (h2 >> 12) | (h3 << 14);
    let hh3 = (h3 >> 18) | (h4 << 8);

    // add s (key[16..32]) mod 2^128
    let s0 = u32::from_le_bytes(key[16..20].try_into().unwrap());
    let s1_ = u32::from_le_bytes(key[20..24].try_into().unwrap());
    let s2_ = u32::from_le_bytes(key[24..28].try_into().unwrap());
    let s3_ = u32::from_le_bytes(key[28..32].try_into().unwrap());

    let mut f: u64 = hh0 as u64 + s0 as u64;
    let t0 = f as u32;
    f = hh1 as u64 + s1_ as u64 + (f >> 32);
    let t1 = f as u32;
    f = hh2 as u64 + s2_ as u64 + (f >> 32);
    let t2 = f as u32;
    f = hh3 as u64 + s3_ as u64 + (f >> 32);
    let t3 = f as u32;

    let mut tag = [0u8; 16];
    tag[0..4].copy_from_slice(&t0.to_le_bytes());
    tag[4..8].copy_from_slice(&t1.to_le_bytes());
    tag[8..12].copy_from_slice(&t2.to_le_bytes());
    tag[12..16].copy_from_slice(&t3.to_le_bytes());
    tag
}

/// Constant-time tag comparison.
pub fn tags_equal(a: &[u8; 16], b: &[u8; 16]) -> bool {
    let mut diff = 0u8;
    for i in 0..16 {
        diff |= a[i] ^ b[i];
    }
    diff == 0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 8439 §2.5.2.
    #[test]
    fn rfc8439_tag() {
        let key = hex::decode_array::<32>(
            "85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b",
        )
        .unwrap();
        let msg = b"Cryptographic Forum Research Group";
        let tag = poly1305(&key, msg);
        assert_eq!(hex::encode(&tag), "a8061dc1305136c6c22b8baf0c0127a9");
    }

    // RFC 8439 A.3 vector #1: all-zero key and message.
    #[test]
    fn zero_key_zero_msg() {
        let tag = poly1305(&[0u8; 32], &[0u8; 64]);
        assert_eq!(tag, [0u8; 16]);
    }

    // RFC 8439 A.3 vector #3: r = 0, s != 0 → tag = s over "message".
    #[test]
    fn r_zero_tag_is_s() {
        let mut key = [0u8; 32];
        key[16..32].copy_from_slice(&hex::decode("36e5f6b5c5e06070f0efca96227a863e").unwrap());
        let msg = b"Any submission to the IETF intended by the Contributor for publi\
cation as all or part of an IETF Internet-Draft or RFC and any statement made wi\
thin the context of an IETF activity is considered an \"IETF Contribution\". Such \
statements include oral statements in IETF sessions, as well as written and elec\
tronic communications made at any time or place, which are addressed to";
        let tag = poly1305(&key, &msg[..]);
        assert_eq!(hex::encode(&tag), "36e5f6b5c5e06070f0efca96227a863e");
    }

    #[test]
    fn different_messages_different_tags() {
        let key = [7u8; 32];
        assert_ne!(poly1305(&key, b"hello"), poly1305(&key, b"hellp"));
        assert_ne!(poly1305(&key, b""), poly1305(&key, b"\x00"));
    }

    #[test]
    fn tags_equal_constant_time_behavior() {
        let a = [1u8; 16];
        let mut b = a;
        assert!(tags_equal(&a, &b));
        b[15] ^= 1;
        assert!(!tags_equal(&a, &b));
    }
}
