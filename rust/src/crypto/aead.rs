//! ChaCha20-Poly1305 AEAD (RFC 8439 §2.8).
//!
//! Stands in for the paper's AES-GCM-128: clients encrypt the Shamir shares
//! `(b_{i,j}, s^{SK}_{i,j})` under the pairwise key `c_{i,j}` before routing
//! them through the (untrusted-channel) server in Step 1.

use super::chacha20::ChaCha20;
use super::poly1305::{poly1305, tags_equal};
use thiserror::Error;

#[derive(Debug, Error, PartialEq, Eq)]
pub enum AeadError {
    #[error("authentication tag mismatch (ciphertext tampered or wrong key)")]
    TagMismatch,
    #[error("ciphertext too short to contain a tag")]
    TooShort,
}

fn pad16(len: usize) -> usize {
    (16 - len % 16) % 16
}

fn compute_tag(otk: &[u8; 32], aad: &[u8], ct: &[u8]) -> [u8; 16] {
    let mut mac_data = Vec::with_capacity(aad.len() + ct.len() + 32);
    mac_data.extend_from_slice(aad);
    mac_data.extend_from_slice(&vec![0u8; pad16(aad.len())]);
    mac_data.extend_from_slice(ct);
    mac_data.extend_from_slice(&vec![0u8; pad16(ct.len())]);
    mac_data.extend_from_slice(&(aad.len() as u64).to_le_bytes());
    mac_data.extend_from_slice(&(ct.len() as u64).to_le_bytes());
    poly1305(otk, &mac_data)
}

fn one_time_key(key: &[u8; 32], nonce: &[u8; 12]) -> [u8; 32] {
    let cipher = ChaCha20::new(key, nonce);
    let mut block = [0u8; 64];
    cipher.block(0, &mut block);
    block[..32].try_into().unwrap()
}

/// Encrypt: returns ciphertext || 16-byte tag.
pub fn seal(key: &[u8; 32], nonce: &[u8; 12], aad: &[u8], plaintext: &[u8]) -> Vec<u8> {
    let cipher = ChaCha20::new(key, nonce);
    let mut out = plaintext.to_vec();
    cipher.apply_keystream(1, &mut out);
    let otk = one_time_key(key, nonce);
    let tag = compute_tag(&otk, aad, &out);
    out.extend_from_slice(&tag);
    out
}

/// Decrypt and verify; returns the plaintext.
pub fn open(
    key: &[u8; 32],
    nonce: &[u8; 12],
    aad: &[u8],
    ct_and_tag: &[u8],
) -> Result<Vec<u8>, AeadError> {
    if ct_and_tag.len() < 16 {
        return Err(AeadError::TooShort);
    }
    let (ct, tag) = ct_and_tag.split_at(ct_and_tag.len() - 16);
    let otk = one_time_key(key, nonce);
    let expect = compute_tag(&otk, aad, ct);
    let tag: [u8; 16] = tag.try_into().unwrap();
    if !tags_equal(&expect, &tag) {
        return Err(AeadError::TagMismatch);
    }
    let cipher = ChaCha20::new(key, nonce);
    let mut out = ct.to_vec();
    cipher.apply_keystream(1, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 8439 §2.8.2 test vector.
    #[test]
    fn rfc8439_seal() {
        let key = hex::decode_array::<32>(
            "808182838485868788898a8b8c8d8e8f909192939495969798999a9b9c9d9e9f",
        )
        .unwrap();
        let nonce = hex::decode_array::<12>("070000004041424344454647").unwrap();
        let aad = hex::decode("50515253c0c1c2c3c4c5c6c7").unwrap();
        let pt = b"Ladies and Gentlemen of the class of '99: If I could offer you \
only one tip for the future, sunscreen would be it.";
        let out = seal(&key, &nonce, &aad, pt);
        let (ct, tag) = out.split_at(out.len() - 16);
        assert_eq!(
            hex::encode(ct),
            "d31a8d34648e60db7b86afbc53ef7ec2a4aded51296e08fea9e2b5a736ee62d6\
             3dbea45e8ca9671282fafb69da92728b1a71de0a9e060b2905d6a5b67ecd3b36\
             92ddbd7f2d778b8c9803aee328091b58fab324e4fad675945585808b4831d7bc\
             3ff4def08e4b7a9de576d26586cec64b6116"
        );
        assert_eq!(hex::encode(tag), "1ae10b594f09e26a7e902ecbd0600691");
    }

    #[test]
    fn round_trip_and_tamper() {
        let key = [9u8; 32];
        let nonce = [1u8; 12];
        let aad = b"header";
        let pt = b"the secret shares";
        let mut ct = seal(&key, &nonce, aad, pt);
        assert_eq!(open(&key, &nonce, aad, &ct).unwrap(), pt.to_vec());

        // flip a ciphertext bit
        ct[0] ^= 1;
        assert_eq!(open(&key, &nonce, aad, &ct), Err(AeadError::TagMismatch));
        ct[0] ^= 1;
        // wrong aad
        assert_eq!(open(&key, &nonce, b"other", &ct), Err(AeadError::TagMismatch));
        // wrong key
        assert_eq!(open(&[8u8; 32], &nonce, aad, &ct), Err(AeadError::TagMismatch));
        // wrong nonce
        assert_eq!(open(&key, &[0u8; 12], aad, &ct), Err(AeadError::TagMismatch));
        // truncated
        assert_eq!(open(&key, &nonce, aad, &ct[..10]), Err(AeadError::TooShort));
    }

    #[test]
    fn empty_plaintext_and_aad() {
        let key = [3u8; 32];
        let nonce = [4u8; 12];
        let ct = seal(&key, &nonce, &[], &[]);
        assert_eq!(ct.len(), 16);
        assert_eq!(open(&key, &nonce, &[], &ct).unwrap(), Vec::<u8>::new());
    }
}
