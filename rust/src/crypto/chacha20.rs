//! ChaCha20 stream cipher (RFC 8439 §2.3–2.4).
//!
//! Used as (a) the protocol PRG expanding mask seeds `b_i` / `s_{i,j}` into
//! Z_{2^b} mask vectors — the hot path of Step 2 — and (b) the cipher half
//! of the ChaCha20-Poly1305 AEAD, and (c) the simulation RNG core.

/// Keystream words (u32) produced per 64-byte ChaCha20 block.
///
/// The seekability contract of `crypto::prg`: keystream word `w` of a
/// stream lives in block `w / WORDS_PER_BLOCK` at lane `w %
/// WORDS_PER_BLOCK`, so any word offset is reachable by seeking the block
/// counter — no prefix of the stream ever needs to be generated.
pub const WORDS_PER_BLOCK: usize = 16;

/// Blocks per vectorized batch ([`ChaCha20::block_words_x16`]), the widest
/// lock-step expansion (one AVX-512 register per state word).
pub const BATCH_BLOCKS: usize = 16;

/// ChaCha20 keystream generator for a fixed (key, nonce).
#[derive(Clone)]
pub struct ChaCha20 {
    /// Initial state words 0..16 minus the counter (word 12).
    state: [u32; 16],
}

const SIGMA: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];

#[inline(always)]
fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(16);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(12);
    s[a] = s[a].wrapping_add(s[b]);
    s[d] = (s[d] ^ s[a]).rotate_left(8);
    s[c] = s[c].wrapping_add(s[d]);
    s[b] = (s[b] ^ s[c]).rotate_left(7);
}

impl ChaCha20 {
    pub fn new(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&SIGMA);
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
        }
        state[12] = 0; // counter, set per block
        for i in 0..3 {
            state[13 + i] = u32::from_le_bytes(nonce[4 * i..4 * i + 4].try_into().unwrap());
        }
        ChaCha20 { state }
    }

    /// Compute the 64-byte block for `counter` into `out`.
    #[inline]
    pub fn block(&self, counter: u32, out: &mut [u8; 64]) {
        let mut ws = [0u32; 16];
        self.block_words(counter, &mut ws);
        for (i, w) in ws.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_le_bytes());
        }
    }

    /// Compute the block for `counter` as 16 little-endian u32 words.
    ///
    /// The mask-expansion hot path consumes words directly (masks live in
    /// Z_{2^32}), skipping the byte serialization round-trip.
    #[inline]
    pub fn block_words(&self, counter: u32, out: &mut [u32; 16]) {
        let mut s = self.state;
        s[12] = counter;
        let init = s;
        for _ in 0..10 {
            // column rounds
            quarter_round(&mut s, 0, 4, 8, 12);
            quarter_round(&mut s, 1, 5, 9, 13);
            quarter_round(&mut s, 2, 6, 10, 14);
            quarter_round(&mut s, 3, 7, 11, 15);
            // diagonal rounds
            quarter_round(&mut s, 0, 5, 10, 15);
            quarter_round(&mut s, 1, 6, 11, 12);
            quarter_round(&mut s, 2, 7, 8, 13);
            quarter_round(&mut s, 3, 4, 9, 14);
        }
        for i in 0..16 {
            out[i] = s[i].wrapping_add(init[i]);
        }
    }

    /// Compute four consecutive blocks (`counter..counter+4`) as 64 u32
    /// words, processed in lock-step so LLVM auto-vectorizes the quarter
    /// rounds across blocks (the §Perf optimization of the PRG hot path:
    /// ~3× over the scalar block on this host — see EXPERIMENTS.md §Perf).
    ///
    /// Output layout: `out[b * 16 + w]` = word `w` of block `b` (i.e. the
    /// natural sequential keystream order).
    #[inline]
    pub fn block_words_x4(&self, counter: u32, out: &mut [u32; 64]) {
        self.block_words_xn::<4>(counter, out);
    }

    /// Eight consecutive blocks — one AVX2/AVX-512 register per state word.
    #[inline]
    pub fn block_words_x8(&self, counter: u32, out: &mut [u32; 128]) {
        self.block_words_xn::<8>(counter, out);
    }

    /// Sixteen consecutive blocks (one AVX-512 register per state word).
    #[inline]
    pub fn block_words_x16(&self, counter: u32, out: &mut [u32; 256]) {
        self.block_words_xn::<16>(counter, out);
    }

    #[inline]
    fn block_words_xn<const N: usize>(&self, counter: u32, out: &mut [u32]) {
        debug_assert_eq!(out.len(), 16 * N);
        // state lanes: s[w][l] = word w of block l
        let mut s = [[0u32; N]; 16];
        for w in 0..16 {
            s[w] = [self.state[w]; N];
        }
        for (b, lane) in s[12].iter_mut().enumerate() {
            *lane = counter.wrapping_add(b as u32);
        }
        let init = s;

        #[inline(always)]
        fn qr<const N: usize>(s: &mut [[u32; N]; 16], a: usize, b: usize, c: usize, d: usize) {
            for l in 0..N {
                s[a][l] = s[a][l].wrapping_add(s[b][l]);
            }
            for l in 0..N {
                s[d][l] = (s[d][l] ^ s[a][l]).rotate_left(16);
            }
            for l in 0..N {
                s[c][l] = s[c][l].wrapping_add(s[d][l]);
            }
            for l in 0..N {
                s[b][l] = (s[b][l] ^ s[c][l]).rotate_left(12);
            }
            for l in 0..N {
                s[a][l] = s[a][l].wrapping_add(s[b][l]);
            }
            for l in 0..N {
                s[d][l] = (s[d][l] ^ s[a][l]).rotate_left(8);
            }
            for l in 0..N {
                s[c][l] = s[c][l].wrapping_add(s[d][l]);
            }
            for l in 0..N {
                s[b][l] = (s[b][l] ^ s[c][l]).rotate_left(7);
            }
        }

        for _ in 0..10 {
            qr(&mut s, 0, 4, 8, 12);
            qr(&mut s, 1, 5, 9, 13);
            qr(&mut s, 2, 6, 10, 14);
            qr(&mut s, 3, 7, 11, 15);
            qr(&mut s, 0, 5, 10, 15);
            qr(&mut s, 1, 6, 11, 12);
            qr(&mut s, 2, 7, 8, 13);
            qr(&mut s, 3, 4, 9, 14);
        }
        for w in 0..16 {
            for l in 0..N {
                out[l * 16 + w] = s[w][l].wrapping_add(init[w][l]);
            }
        }
    }

    /// XOR the keystream (starting at block `counter`) into `data` in place.
    pub fn apply_keystream(&self, mut counter: u32, data: &mut [u8]) {
        let mut block = [0u8; 64];
        for chunk in data.chunks_mut(64) {
            self.block(counter, &mut block);
            for (d, k) in chunk.iter_mut().zip(block.iter()) {
                *d ^= k;
            }
            counter = counter.wrapping_add(1);
        }
    }

    /// Encrypt/decrypt convenience (allocating).
    pub fn process(&self, counter: u32, data: &[u8]) -> Vec<u8> {
        let mut out = data.to_vec();
        self.apply_keystream(counter, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hex;

    // RFC 8439 §2.3.2 test vector.
    #[test]
    fn rfc8439_block() {
        let key: [u8; 32] = (0u8..32).collect::<Vec<_>>().try_into().unwrap();
        let nonce = hex::decode_array::<12>("000000090000004a00000000").unwrap();
        let c = ChaCha20::new(&key, &nonce);
        let mut out = [0u8; 64];
        c.block(1, &mut out);
        let expect = hex::decode(
            "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e\
             d2826446079faa0914c2d705d98b02a2b5129cd1de164eb9cbd083e8a2503c4e",
        )
        .unwrap();
        assert_eq!(out.to_vec(), expect);
    }

    // RFC 8439 §2.4.2 encryption test vector.
    #[test]
    fn rfc8439_encrypt() {
        let key: [u8; 32] = (0u8..32).collect::<Vec<_>>().try_into().unwrap();
        let nonce = hex::decode_array::<12>("000000000000004a00000000").unwrap();
        let plaintext = b"Ladies and Gentlemen of the class of '99: If I could offer you \
only one tip for the future, sunscreen would be it.";
        let c = ChaCha20::new(&key, &nonce);
        let ct = c.process(1, plaintext);
        let expect = hex::decode(
            "6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b\
             f91b65c5524733ab8f593dabcd62b3571639d624e65152ab8f530c359f0861d8\
             07ca0dbf500d6a6156a38e088a22b65e52bc514d16ccf806818ce91ab7793736\
             5af90bbf74a35be6b40b8eedf2785e42874d",
        )
        .unwrap();
        assert_eq!(ct, expect);
        // decrypt round-trip
        assert_eq!(c.process(1, &ct), plaintext.to_vec());
    }

    #[test]
    fn keystream_blocks_differ_by_counter() {
        let c = ChaCha20::new(&[7u8; 32], &[1u8; 12]);
        let mut b0 = [0u8; 64];
        let mut b1 = [0u8; 64];
        c.block(0, &mut b0);
        c.block(1, &mut b1);
        assert_ne!(b0, b1);
        // deterministic
        let mut b0b = [0u8; 64];
        c.block(0, &mut b0b);
        assert_eq!(b0, b0b);
    }

    #[test]
    fn block_words_match_block_bytes() {
        let c = ChaCha20::new(&[3u8; 32], &[9u8; 12]);
        let mut bytes = [0u8; 64];
        let mut words = [0u32; 16];
        c.block(5, &mut bytes);
        c.block_words(5, &mut words);
        for i in 0..16 {
            assert_eq!(words[i], u32::from_le_bytes(bytes[4 * i..4 * i + 4].try_into().unwrap()));
        }
    }

    #[test]
    fn block_words_x4_matches_scalar_blocks() {
        let c = ChaCha20::new(&[0x42u8; 32], &[6u8; 12]);
        let mut quad = [0u32; 64];
        c.block_words_x4(100, &mut quad);
        for b in 0..4u32 {
            let mut single = [0u32; 16];
            c.block_words(100 + b, &mut single);
            assert_eq!(&quad[(b as usize) * 16..(b as usize + 1) * 16], &single[..], "block {b}");
        }
        // counter wrap-around edge
        c.block_words_x4(u32::MAX - 1, &mut quad);
        let mut single = [0u32; 16];
        c.block_words(u32::MAX, &mut single);
        assert_eq!(&quad[16..32], &single[..]);
    }

    #[test]
    fn batched_blocks_are_counter_seekable() {
        // a batch started at an arbitrary counter equals the scalar blocks
        // at the same counters — the invariant the mask sharding relies on
        let c = ChaCha20::new(&[0x33u8; 32], &[4u8; 12]);
        for start in [0u32, 1, 7, 16, 1000] {
            let mut batch = [0u32; 16 * BATCH_BLOCKS];
            c.block_words_x16(start, &mut batch);
            for b in 0..BATCH_BLOCKS as u32 {
                let mut single = [0u32; WORDS_PER_BLOCK];
                c.block_words(start + b, &mut single);
                let lo = (b as usize) * WORDS_PER_BLOCK;
                assert_eq!(&batch[lo..lo + WORDS_PER_BLOCK], &single[..], "start={start} b={b}");
            }
        }
    }

    #[test]
    fn apply_keystream_partial_blocks() {
        let c = ChaCha20::new(&[5u8; 32], &[2u8; 12]);
        let msg = vec![0xABu8; 150]; // 2 full blocks + 22 bytes
        let ct = c.process(0, &msg);
        assert_eq!(c.process(0, &ct), msg);
        assert_ne!(ct, msg);
    }
}
