//! Simulated star network between n clients and the server, with exact
//! byte accounting per protocol step and direction.
//!
//! The paper's Table 1 and Appendix C are statements about *communication
//! bandwidth*; this module is the measurement instrument: every protocol
//! message declares its wire size and is charged to (step, direction,
//! client). The Table-1 scaling bench then fits log–log slopes against n.

pub mod socket;

/// Direction of a message on the star topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    /// client → server
    Up,
    /// server → client
    Down,
}

/// Byte/message counters for one protocol round.
///
/// `PartialEq`/`Eq` support the differential harness (`sim::differential`),
/// which asserts that the sync engine and the threaded coordinator charge
/// bit-identical traffic for the same round.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct NetStats {
    /// bytes_up[step] — total client→server bytes in protocol step 0..=3
    pub bytes_up: [u64; 4],
    pub bytes_down: [u64; 4],
    pub msgs_up: [u64; 4],
    pub msgs_down: [u64; 4],
    /// Bytes of masked field elements in Step-2 uploads — the payload the
    /// codec layer shrinks. Dense: |V3|·m·b/8; a k-sparse codec cuts this
    /// to |V3|·k·b/8, and the ratio is the measured bandwidth saving.
    pub masked_payload_bytes: u64,
    /// per-client totals across all steps (index = client id)
    pub client_up: Vec<u64>,
    pub client_down: Vec<u64>,
    /// Bytes observed at the socket, client → server: frame payloads plus
    /// the length prefix, header and explicit counts the wire codec adds
    /// (see `crate::wire`). Zero for the in-process executors — only
    /// `net::socket` measures a real wire, so differential comparisons
    /// against the engine go through [`NetStats::logical_eq`].
    pub framed_up: u64,
    /// Bytes observed at the socket, server → client.
    pub framed_down: u64,
    /// Coordinate-map traffic of the deployment-grade TopK path: local
    /// support uploads (warm phase 0) plus any explicit plan downloads.
    /// A subset of the step-0/1 bytes already charged via
    /// [`NetStats::record`], tracked separately so the codec's map cost is
    /// measurable and excluded from [`NetStats::setup_bytes`].
    pub coord_map_bytes: u64,
    /// Session re-key traffic, client → server: fresh public keys and
    /// cold-style AEAD share ciphertexts sent because the ratchet forced a
    /// re-key. On a cold round this is *all* step-0/1 upload bytes.
    pub rekey_up: u64,
    /// Session re-key traffic, server → client (key bundles / replacement
    /// neighbor keys / re-dealt share deliveries).
    pub rekey_down: u64,
    /// timeout_drops[step] — clients the server dropped at the phase-`step`
    /// deadline (virtual-clock event loop or wire `TimeoutPolicy`): the
    /// client produced its message too late, the server closed the phase
    /// without it, and from then on it is indistinguishable from a churned
    /// client. Zero on untimed executors.
    pub timeout_drops: [u64; 4],
}

impl NetStats {
    pub fn new(n: usize) -> NetStats {
        NetStats {
            client_up: vec![0; n],
            client_down: vec![0; n],
            ..Default::default()
        }
    }

    /// Charge one message. Out-of-range inputs are caller bugs (a socket
    /// front end must validate wire-supplied client ids *before* charging),
    /// so both asserts name exactly what went wrong instead of leaving an
    /// anonymous index panic in the accounting layer.
    pub fn record(&mut self, step: usize, dir: Dir, client: usize, bytes: usize) {
        assert!(step < 4, "NetStats::record: step {step} out of range (protocol has steps 0..=3)");
        match dir {
            Dir::Up => {
                assert!(
                    client < self.client_up.len(),
                    "NetStats::record: client id {client} out of range (n = {})",
                    self.client_up.len()
                );
                self.bytes_up[step] += bytes as u64;
                self.msgs_up[step] += 1;
                self.client_up[client] += bytes as u64;
            }
            Dir::Down => {
                assert!(
                    client < self.client_down.len(),
                    "NetStats::record: client id {client} out of range (n = {})",
                    self.client_down.len()
                );
                self.bytes_down[step] += bytes as u64;
                self.msgs_down[step] += 1;
                self.client_down[client] += bytes as u64;
            }
        }
    }

    /// Count raw socket bytes (whole frames as read/written, including
    /// framing overhead). Only the socket transport calls this.
    pub fn record_framed(&mut self, dir: Dir, bytes: usize) {
        match dir {
            Dir::Up => self.framed_up += bytes as u64,
            Dir::Down => self.framed_down += bytes as u64,
        }
    }

    /// Charge the masked-value payload of one Step-2 upload (a subset of
    /// the bytes already charged via [`NetStats::record`] — tracked
    /// separately so per-codec savings are directly measurable).
    pub fn record_masked_payload(&mut self, bytes: usize) {
        self.masked_payload_bytes += bytes as u64;
    }

    /// Charge coordinate-map bytes (a subset of already-recorded traffic).
    pub fn record_coord_map(&mut self, bytes: usize) {
        self.coord_map_bytes += bytes as u64;
    }

    /// Charge session re-key bytes (a subset of already-recorded traffic).
    pub fn record_rekey(&mut self, dir: Dir, bytes: usize) {
        match dir {
            Dir::Up => self.rekey_up += bytes as u64,
            Dir::Down => self.rekey_down += bytes as u64,
        }
    }

    /// Classify one client as timeout-dropped at the `step` phase deadline.
    /// Its late message is discarded unread, so no bytes are charged — the
    /// counter records the *decision*, which the differential harness
    /// compares bit-for-bit across executors.
    pub fn record_timeout_drop(&mut self, step: usize) {
        assert!(
            step < 4,
            "NetStats::record_timeout_drop: step {step} out of range (protocol has steps 0..=3)"
        );
        self.timeout_drops[step] += 1;
    }

    /// Setup traffic of the round: steps 0–1 in both directions, minus the
    /// coordinate-map bytes (which pay for the codec, not for keys/shares).
    /// This is the quantity the session layer amortizes — warm rounds must
    /// push it far below a cold start (the `session-steady-state` CI gate).
    pub fn setup_bytes(&self) -> u64 {
        let gross: u64 = self.bytes_up[..2].iter().sum::<u64>()
            + self.bytes_down[..2].iter().sum::<u64>();
        gross - self.coord_map_bytes
    }

    /// Total bytes through the server (both directions, all steps).
    pub fn server_total(&self) -> u64 {
        self.bytes_up.iter().sum::<u64>() + self.bytes_down.iter().sum::<u64>()
    }

    /// Mean per-client bandwidth (up + down) over clients that sent
    /// anything.
    pub fn mean_client_total(&self) -> f64 {
        let active: Vec<u64> = self
            .client_up
            .iter()
            .zip(&self.client_down)
            .map(|(u, d)| u + d)
            .filter(|&t| t > 0)
            .collect();
        if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<u64>() as f64 / active.len() as f64
        }
    }

    /// Max per-client bandwidth.
    pub fn max_client_total(&self) -> u64 {
        self.client_up
            .iter()
            .zip(&self.client_down)
            .map(|(u, d)| u + d)
            .max()
            .unwrap_or(0)
    }

    pub fn merge(&mut self, other: &NetStats) {
        for s in 0..4 {
            self.bytes_up[s] += other.bytes_up[s];
            self.bytes_down[s] += other.bytes_down[s];
            self.msgs_up[s] += other.msgs_up[s];
            self.msgs_down[s] += other.msgs_down[s];
        }
        self.masked_payload_bytes += other.masked_payload_bytes;
        self.framed_up += other.framed_up;
        self.framed_down += other.framed_down;
        self.coord_map_bytes += other.coord_map_bytes;
        self.rekey_up += other.rekey_up;
        self.rekey_down += other.rekey_down;
        for s in 0..4 {
            self.timeout_drops[s] += other.timeout_drops[s];
        }
        // the two per-client vectors are independent dimensions: each one
        // resizes under its own length check (resizing client_down under a
        // client_up guard dropped bytes whenever the lengths diverged)
        if self.client_up.len() < other.client_up.len() {
            self.client_up.resize(other.client_up.len(), 0);
        }
        if self.client_down.len() < other.client_down.len() {
            self.client_down.resize(other.client_down.len(), 0);
        }
        for (i, u) in other.client_up.iter().enumerate() {
            self.client_up[i] += u;
        }
        for (i, d) in other.client_down.iter().enumerate() {
            self.client_down[i] += d;
        }
    }

    /// [`NetStats::merge`] with `other`'s per-client traffic re-homed at a
    /// global-id offset. Hierarchical roll-up: shard s covers the
    /// contiguous id range `[offset, offset + m)`, so its local client i is
    /// the global client `offset + i`. Aggregate (per-step / framed /
    /// payload) counters merge unchanged.
    ///
    /// `offset + other.n` must not overflow `usize`: a wild offset is a
    /// caller bug (a shard plan never produces one), and the named assert
    /// beats an opaque capacity-overflow panic inside `Vec::resize`. Note
    /// the id spaces are *not* checked for disjointness — calling
    /// `merge_at` twice with overlapping ranges silently sums the
    /// overlapping clients' traffic, which is the documented (mis)use
    /// semantics pinned by tests.
    pub fn merge_at(&mut self, other: &NetStats, offset: usize) {
        for s in 0..4 {
            self.bytes_up[s] += other.bytes_up[s];
            self.bytes_down[s] += other.bytes_down[s];
            self.msgs_up[s] += other.msgs_up[s];
            self.msgs_down[s] += other.msgs_down[s];
        }
        self.masked_payload_bytes += other.masked_payload_bytes;
        self.framed_up += other.framed_up;
        self.framed_down += other.framed_down;
        self.coord_map_bytes += other.coord_map_bytes;
        self.rekey_up += other.rekey_up;
        self.rekey_down += other.rekey_down;
        for s in 0..4 {
            self.timeout_drops[s] += other.timeout_drops[s];
        }
        let up_end = offset.checked_add(other.client_up.len()).unwrap_or_else(|| {
            panic!(
                "NetStats::merge_at: offset {offset} + {} clients overflows the id space",
                other.client_up.len()
            )
        });
        let down_end = offset.checked_add(other.client_down.len()).unwrap_or_else(|| {
            panic!(
                "NetStats::merge_at: offset {offset} + {} clients overflows the id space",
                other.client_down.len()
            )
        });
        if self.client_up.len() < up_end {
            self.client_up.resize(up_end, 0);
        }
        if self.client_down.len() < down_end {
            self.client_down.resize(down_end, 0);
        }
        for (i, u) in other.client_up.iter().enumerate() {
            self.client_up[offset + i] += u;
        }
        for (i, d) in other.client_down.iter().enumerate() {
            self.client_down[offset + i] += d;
        }
    }

    /// Equality over the *logical* (Appendix-C) accounting only, ignoring
    /// the framed-byte dimension. The differential harness compares
    /// executors with this: the socket transport must charge bit-identical
    /// logical traffic to the in-process engine, while its framed counters
    /// are legitimately nonzero only on the wire.
    pub fn logical_eq(&self, other: &NetStats) -> bool {
        self.bytes_up == other.bytes_up
            && self.bytes_down == other.bytes_down
            && self.msgs_up == other.msgs_up
            && self.msgs_down == other.msgs_down
            && self.masked_payload_bytes == other.masked_payload_bytes
            && self.coord_map_bytes == other.coord_map_bytes
            && self.rekey_up == other.rekey_up
            && self.rekey_down == other.rekey_down
            && self.timeout_drops == other.timeout_drops
            && self.client_up == other.client_up
            && self.client_down == other.client_down
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_totals() {
        let mut s = NetStats::new(3);
        s.record(0, Dir::Up, 0, 100);
        s.record(0, Dir::Down, 0, 50);
        s.record(2, Dir::Up, 1, 200);
        assert_eq!(s.bytes_up[0], 100);
        assert_eq!(s.bytes_down[0], 50);
        assert_eq!(s.bytes_up[2], 200);
        assert_eq!(s.server_total(), 350);
        assert_eq!(s.client_up[0], 100);
        assert_eq!(s.client_down[0], 50);
        assert_eq!(s.max_client_total(), 200);
        // mean over active clients (0 and 1)
        assert!((s.mean_client_total() - 175.0).abs() < 1e-9);
    }

    #[test]
    fn merge_adds_counters() {
        let mut a = NetStats::new(2);
        a.record(1, Dir::Up, 0, 10);
        a.record_masked_payload(7);
        let mut b = NetStats::new(2);
        b.record(1, Dir::Up, 1, 20);
        b.record(3, Dir::Down, 0, 5);
        b.record_masked_payload(11);
        a.merge(&b);
        assert_eq!(a.bytes_up[1], 30);
        assert_eq!(a.bytes_down[3], 5);
        assert_eq!(a.msgs_up[1], 2);
        assert_eq!(a.client_up[1], 20);
        assert_eq!(a.masked_payload_bytes, 18);
    }

    #[test]
    fn merge_handles_uneven_client_vectors() {
        // regression: merge used to resize client_down only when client_up
        // was short, silently dropping per-client bytes past the zip end
        let mut a = NetStats::new(1);
        a.record(0, Dir::Down, 0, 3);
        let mut b = NetStats::new(4);
        b.record(0, Dir::Up, 3, 10);
        b.record(0, Dir::Down, 2, 20);
        a.merge(&b);
        assert_eq!(a.client_up, vec![0, 0, 0, 10]);
        assert_eq!(a.client_down, vec![3, 0, 20, 0]);
        // and the opposite orientation: self longer than other
        let mut c = NetStats::new(4);
        c.record(1, Dir::Up, 3, 7);
        let mut d = NetStats::new(1);
        d.record(1, Dir::Down, 0, 9);
        c.merge(&d);
        assert_eq!(c.client_up, vec![0, 0, 0, 7]);
        assert_eq!(c.client_down, vec![9, 0, 0, 0]);
    }

    #[test]
    fn framed_bytes_merge_but_do_not_affect_logical_eq() {
        let mut a = NetStats::new(2);
        a.record(2, Dir::Up, 0, 40);
        let mut b = a.clone();
        b.record_framed(Dir::Up, 46);
        b.record_framed(Dir::Down, 10);
        assert_ne!(a, b);
        assert!(a.logical_eq(&b), "framed counters must not break logical equality");
        b.record(2, Dir::Up, 1, 1);
        assert!(!a.logical_eq(&b), "logical_eq still sees real traffic differences");

        let mut c = NetStats::new(2);
        c.record_framed(Dir::Up, 4);
        c.merge(&b);
        assert_eq!(c.framed_up, 50);
        assert_eq!(c.framed_down, 10);
    }

    #[test]
    fn coord_map_and_rekey_counters_merge_and_gate_logical_eq() {
        let mut a = NetStats::new(2);
        a.record(0, Dir::Up, 0, 100);
        a.record(1, Dir::Down, 0, 50);
        a.record(2, Dir::Up, 0, 500);
        let mut b = a.clone();
        assert!(a.logical_eq(&b));
        b.record_coord_map(12);
        assert!(!a.logical_eq(&b), "coordinate-map bytes are logical traffic");
        a.record_coord_map(12);
        a.record_rekey(Dir::Up, 64);
        assert!(!a.logical_eq(&b), "re-key accounting is logical traffic");
        b.record_rekey(Dir::Up, 64);
        assert!(a.logical_eq(&b));

        // setup_bytes = step 0–1 both directions minus the coordinate map
        assert_eq!(a.setup_bytes(), 100 + 50 - 12);

        let mut c = NetStats::new(2);
        c.record_coord_map(3);
        c.record_rekey(Dir::Down, 7);
        c.merge(&a);
        assert_eq!(c.coord_map_bytes, 15);
        assert_eq!(c.rekey_up, 64);
        assert_eq!(c.rekey_down, 7);
    }

    #[test]
    fn timeout_drops_merge_and_gate_logical_eq() {
        let mut a = NetStats::new(2);
        a.record(0, Dir::Up, 0, 10);
        let mut b = a.clone();
        assert!(a.logical_eq(&b));
        b.record_timeout_drop(2);
        b.record_timeout_drop(2);
        b.record_timeout_drop(3);
        assert_eq!(b.timeout_drops, [0, 0, 2, 1]);
        assert!(
            !a.logical_eq(&b),
            "a timeout classification is a logical difference between executors"
        );
        a.merge(&b);
        assert_eq!(a.timeout_drops, [0, 0, 2, 1]);
        let mut c = NetStats::new(1);
        c.record_timeout_drop(2);
        c.merge_at(&b, 5);
        assert_eq!(c.timeout_drops, [0, 0, 3, 1]);
    }

    #[test]
    #[should_panic(expected = "step 4 out of range")]
    fn timeout_drop_rejects_invalid_step() {
        let mut s = NetStats::new(1);
        s.record_timeout_drop(4);
    }

    #[test]
    fn merge_at_rehomes_per_client_traffic() {
        let mut root = NetStats::new(2);
        root.record(0, Dir::Up, 1, 5);
        let mut shard = NetStats::new(3);
        shard.record(2, Dir::Up, 0, 100);
        shard.record(2, Dir::Down, 2, 7);
        root.merge_at(&shard, 4);
        assert_eq!(root.client_up, vec![0, 5, 0, 0, 100, 0, 0]);
        assert_eq!(root.client_down, vec![0, 0, 0, 0, 0, 0, 7]);
        assert_eq!(root.bytes_up[2], 100);
    }

    #[test]
    fn merge_at_overlapping_id_spaces_sum_per_client() {
        // Documented misuse semantics: merge_at does not police
        // disjointness, so overlapping ranges sum the overlap. A shard
        // plan's ranges are disjoint by construction; anything else is on
        // the caller, and this pin keeps the behavior from drifting
        // silently.
        let mut agg = NetStats::new(0);
        let mut shard = NetStats::new(2);
        shard.record(0, Dir::Up, 0, 10);
        shard.record(0, Dir::Up, 1, 20);
        agg.merge_at(&shard, 0);
        agg.merge_at(&shard, 1); // overlaps global id 1
        assert_eq!(agg.client_up, vec![10, 30, 20]);
        assert_eq!(agg.bytes_up[0], 60, "aggregate counters double-count too");
    }

    #[test]
    #[should_panic(expected = "overflows the id space")]
    fn merge_at_offset_overflow_panics_with_named_message() {
        let mut a = NetStats::new(1);
        let b = NetStats::new(2);
        a.merge_at(&b, usize::MAX - 1);
    }

    #[test]
    #[should_panic]
    fn rejects_invalid_step() {
        let mut s = NetStats::new(1);
        s.record(4, Dir::Up, 0, 1);
    }

    #[test]
    #[should_panic(expected = "client id 5 out of range (n = 2)")]
    fn rejects_out_of_range_client_with_a_named_message() {
        let mut s = NetStats::new(2);
        s.record(0, Dir::Up, 5, 1);
    }
}
