//! Loopback-scale TCP transport for one aggregation round.
//!
//! `protocol::engine` and the `coordinator` event loop move [`Up`]/[`Down`]
//! values through memory; this module moves the same messages as
//! length-prefixed frames (`crate::wire`) over real sockets:
//!
//! * [`serve`] — the server side. Accepts `cfg.n` connections on a
//!   listener, then runs the four protocol phases as the event loop does:
//!   broadcast the phase's `Down` frames, poll every connection
//!   (nonblocking read/write sweeps) until each awaited client answered or
//!   died, decode and validate the `Up` frames, and hand them to
//!   [`Server`] in client-id order. Malformed frames close the offending
//!   connection; replayed or stale frames are discarded by phase — both
//!   without disturbing the round for honest clients.
//! * [`drive_clients`] — the client side: n poll-able [`ClientSm`]s behind
//!   n blocking loopback sockets, stepped in parallel sweeps exactly like
//!   the event loop's lanes.
//! * [`run_round_wire`] — both halves wired together on an ephemeral
//!   loopback port; the shape the differential harness runs as the `wire`
//!   executor.
//!
//! Accounting: logical (Appendix-C) byte charges replicate the event loop
//! exactly — `Start`/`Finish` and `Dropped`/`Failed` cost nothing — so a
//! round over sockets is `NetStats::logical_eq` to the in-process engine.
//! On top of that, `framed_up`/`framed_down` count raw bytes as read from
//! and written to the sockets, framing overhead and duplicates included.

use crate::codec::IndexPlan;
use crate::coordinator::{derive_round_setup, event_loop_workers, CoordRoundResult};
use crate::graph::Graph;
use crate::net::{Dir, NetStats};
use crate::protocol::client::ClientSm;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, Server};
use crate::protocol::{ClientId, ProtocolConfig};
use crate::wire;
use anyhow::{bail, Context, Result};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default wall-clock budget for a whole round (accept + 4 phases).
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

/// Sleep between poll sweeps when nothing moved.
const POLL_PAUSE: Duration = Duration::from_micros(200);

/// The round tag stamped into every frame header, derived from the config
/// seed so both endpoints agree without negotiation.
pub fn round_tag(seed: u64) -> u32 {
    (seed ^ (seed >> 32)) as u32
}

/// One accepted connection: nonblocking stream plus reassembly and
/// write-behind buffers, and the per-phase conversation state.
struct Conn {
    stream: TcpStream,
    rx: wire::FrameBuffer,
    tx: Vec<u8>,
    tx_pos: usize,
    /// Claimed client id — set by the first valid phase-0 frame.
    id: Option<ClientId>,
    open: bool,
    /// The server delivered this phase's `Down` and expects exactly one
    /// `Up` back (the [`ClientSm::step`] contract).
    awaiting: bool,
    /// The phase answer, parked until the phase barrier harvests it.
    slot: Option<Up>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rx: wire::FrameBuffer::new(),
            tx: Vec::new(),
            tx_pos: 0,
            id: None,
            open: true,
            awaiting: false,
            slot: None,
        }
    }

    fn queue(&mut self, frame: &[u8]) {
        if self.open {
            self.tx.extend_from_slice(frame);
        }
    }

    /// Write as much buffered tx as the socket accepts right now; returns
    /// bytes written. Never blocks.
    fn flush(&mut self) -> usize {
        let mut written = 0;
        while self.open && self.tx_pos < self.tx.len() {
            match self.stream.write(&self.tx[self.tx_pos..]) {
                Ok(0) => self.close(),
                Ok(k) => {
                    self.tx_pos += k;
                    written += k;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log::debug!("write to client {:?} failed: {e}", self.id);
                    self.close();
                }
            }
        }
        if self.tx_pos == self.tx.len() {
            self.tx.clear();
            self.tx_pos = 0;
        }
        written
    }

    /// Drain the socket into the frame buffer; returns bytes read. Never
    /// blocks. EOF or a hard error closes the connection — frames already
    /// buffered are still decoded afterwards.
    fn pump(&mut self) -> usize {
        let mut total = 0;
        let mut tmp = [0u8; 16 * 1024];
        while self.open {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.open = false;
                    self.awaiting = false;
                    break;
                }
                Ok(k) => {
                    self.rx.extend(&tmp[..k]);
                    total += k;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log::debug!("read from client {:?} failed: {e}", self.id);
                    self.close();
                }
            }
        }
        total
    }

    fn close(&mut self) {
        self.open = false;
        self.awaiting = false;
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Decode buffered frames on one connection during the given phase.
///
/// A connection parks at most one `Up` per phase (`slot`); once it is
/// filled, further buffered frames wait — if they belong to this phase they
/// are duplicates and the next phase's sweep discards them by the
/// `Up::phase` check. Frame-level garbage closes the connection; a
/// mismatched round tag, a stale/replayed phase, or a spoofed sender id
/// only discards the frame, so one bad message never aborts the round for
/// honest clients.
fn drain_frames(
    c: &mut Conn,
    ci: usize,
    claimed: &mut [Option<usize>],
    plan: &Arc<IndexPlan>,
    round: u32,
    phase: u8,
) {
    while c.slot.is_none() {
        let body = match c.rx.next_frame() {
            Ok(Some(b)) => b,
            Ok(None) => return,
            Err(e) => {
                log::debug!("conn {ci}: bad frame ({e}); closing");
                c.close();
                return;
            }
        };
        let (r, up) = match wire::decode_up(&body, plan) {
            Ok(v) => v,
            Err(e) => {
                log::debug!("conn {ci}: undecodable message ({e}); closing");
                c.close();
                return;
            }
        };
        if r != round {
            log::debug!("conn {ci}: frame tagged round {r}, serving {round}; discarded");
            continue;
        }
        if up.phase() != phase {
            log::debug!(
                "conn {ci}: discarding phase-{} message during phase {phase} (replay or stale)",
                up.phase()
            );
            continue;
        }
        let from = up.from();
        match c.id {
            None => {
                // the first valid frame claims the connection's client id
                if from >= claimed.len() {
                    log::debug!("conn {ci}: claims out-of-range id {from}; closing");
                    c.close();
                    return;
                }
                if claimed[from].is_some() {
                    log::debug!("conn {ci}: id {from} already claimed; closing");
                    c.close();
                    return;
                }
                claimed[from] = Some(ci);
                c.id = Some(from);
            }
            Some(id) if id != from => {
                log::debug!("conn {ci} (client {id}): spoofed sender {from}; discarded");
                continue;
            }
            Some(_) => {}
        }
        c.slot = Some(up);
        c.awaiting = false;
    }
}

/// The server side of one round: connections, the id → connection claim
/// table, and the accumulating byte accounting.
struct Exchange {
    conns: Vec<Conn>,
    claimed: Vec<Option<usize>>,
    stats: NetStats,
    plan: Arc<IndexPlan>,
    round: u32,
    deadline: Instant,
}

impl Exchange {
    /// Encode one `Down` and queue it for the connection claiming `id`,
    /// marking it awaited. The caller charges logical stats separately
    /// (unconditionally, for parity with the in-process executors).
    fn send(&mut self, id: ClientId, down: &Down) {
        self.send_frame(id, &wire::encode_down(self.round, down));
    }

    fn send_frame(&mut self, id: ClientId, frame: &[u8]) {
        match self.claimed.get(id).copied().flatten() {
            Some(ci) if self.conns[ci].open => {
                self.conns[ci].queue(frame);
                self.conns[ci].awaiting = true;
            }
            _ => log::debug!("no live connection claims client {id}; down frame dropped"),
        }
    }

    /// One phase barrier: flush pending writes, pump awaited connections,
    /// decode their answers, and return once no open connection is still
    /// awaited. Yields the parked `Up`s sorted by sender id — the same
    /// order the event loop drains its lanes in.
    fn collect(&mut self, phase: u8) -> Result<Vec<Up>> {
        let deadline = self.deadline;
        loop {
            let mut outstanding = 0;
            let Exchange { conns, claimed, stats, plan, round, .. } = self;
            for (ci, c) in conns.iter_mut().enumerate() {
                let written = c.flush();
                if written > 0 {
                    stats.record_framed(Dir::Down, written);
                }
                if c.open && c.awaiting {
                    let read = c.pump();
                    if read > 0 {
                        stats.record_framed(Dir::Up, read);
                    }
                    drain_frames(c, ci, claimed, plan, *round, phase);
                }
                if c.open && c.awaiting {
                    outstanding += 1;
                }
            }
            if outstanding == 0 {
                break;
            }
            if Instant::now() >= deadline {
                bail!("phase {phase}: timed out with {outstanding} clients still outstanding");
            }
            std::thread::sleep(POLL_PAUSE);
        }
        let mut ups: Vec<Up> = self.conns.iter_mut().filter_map(|c| c.slot.take()).collect();
        ups.sort_by_key(|u| u.from());
        Ok(ups)
    }
}

/// Serve one aggregation round to `cfg.n` socket clients.
///
/// `plan` and `graph` must come from the round's [`derive_round_setup`] so
/// the server validates incoming `Masked` frames against the same index
/// plan the clients encode with. Aborts (|V_k| < t) propagate as `Err`
/// after the connections are dropped, which the honest driver observes as
/// mid-round EOF — both sides fail, matching the engine's abort shape.
pub fn serve(
    listener: &TcpListener,
    cfg: &ProtocolConfig,
    plan: Arc<IndexPlan>,
    graph: Graph,
    round: u32,
    timeout: Duration,
) -> Result<CoordRoundResult> {
    let deadline = Instant::now() + timeout;
    listener.set_nonblocking(true).context("set_nonblocking on listener")?;
    let mut conns = Vec::with_capacity(cfg.n);
    while conns.len() < cfg.n {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                stream.set_nonblocking(true).context("set_nonblocking on accepted stream")?;
                conns.push(Conn::new(stream));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!("accepted {} of {} connections before timeout", conns.len(), cfg.n);
                }
                std::thread::sleep(POLL_PAUSE);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("accept"),
        }
    }

    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, plan.clone(), graph);
    let mut ex = Exchange {
        conns,
        claimed: vec![None; cfg.n],
        stats: NetStats::new(cfg.n),
        plan,
        round,
        deadline,
    };

    // ---- phase 0: advertise keys (Start itself carries no logical bytes)
    let start = wire::encode_down(round, &Down::Start);
    for c in ex.conns.iter_mut() {
        c.queue(&start);
        c.awaiting = true;
    }
    let mut advs = Vec::new();
    for up in ex.collect(0)? {
        match up {
            Up::Adv(a) => {
                ex.stats.record(0, Dir::Up, a.id, a.size_bytes());
                advs.push(a);
            }
            Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
            Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
            other => bail!("protocol order violation in phase 0: {other:?}"),
        }
    }
    let bundles = server.step0_route_keys(advs)?;
    for (id, b) in bundles {
        ex.stats.record(0, Dir::Down, id, b.size_bytes());
        ex.send(id, &Down::Bundle(b));
    }

    // ---- phase 1: share keys
    let mut uploads = Vec::new();
    for up in ex.collect(1)? {
        match up {
            Up::Shares(u) => {
                ex.stats.record(1, Dir::Up, u.from, u.size_bytes());
                uploads.push(u);
            }
            Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
            Up::Failed(id, step, e) => log::debug!("client {id} withdrew step {step}: {e}"),
            other => bail!("protocol order violation in phase 1: {other:?}"),
        }
    }
    let deliveries = server.step1_route_shares(uploads)?;
    for (id, d) in deliveries {
        ex.stats.record(1, Dir::Down, id, d.size_bytes());
        ex.send(id, &Down::Delivery(d));
    }

    // ---- phase 2: masked inputs
    let mut masked = Vec::new();
    for up in ex.collect(2)? {
        match up {
            Up::Masked(m) => {
                ex.stats.record(2, Dir::Up, m.id, m.size_bytes());
                ex.stats.record_masked_payload(m.payload_bytes());
                masked.push(m);
            }
            Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
            Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
            other => bail!("protocol order violation in phase 2: {other:?}"),
        }
    }
    let announce = Arc::new(server.step2_collect_masked(masked)?);
    // one broadcast: encode once, queue the same frame per V3 member
    let frame = wire::encode_down(round, &Down::Announce(announce.clone()));
    for &id in &announce.v3 {
        ex.stats.record(2, Dir::Down, id, announce.size_bytes());
        ex.send_frame(id, &frame);
    }

    // ---- phase 3: unmask shares
    let mut responses = Vec::new();
    for up in ex.collect(3)? {
        match up {
            Up::Unmask(u) => {
                ex.stats.record(3, Dir::Up, u.from, u.size_bytes());
                responses.push(u);
            }
            Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
            Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
            other => bail!("protocol order violation in phase 3: {other:?}"),
        }
    }
    let RoundOutput { sum, reliable, sets } = server.finalize(responses)?;

    // Round over: tell anyone still connected, then flush best-effort.
    // V3 clients close after their Unmask, so this usually reaches nobody.
    let fin = wire::encode_down(round, &Down::Finish);
    for c in ex.conns.iter_mut() {
        if c.open {
            c.queue(&fin);
        }
    }
    let grace = Instant::now() + Duration::from_millis(250);
    loop {
        let mut pending = false;
        for c in ex.conns.iter_mut() {
            let written = c.flush();
            if written > 0 {
                ex.stats.record_framed(Dir::Down, written);
            }
            pending |= c.open && c.tx_pos < c.tx.len();
        }
        if !pending || Instant::now() >= grace {
            break;
        }
        std::thread::sleep(POLL_PAUSE);
    }

    Ok(CoordRoundResult { sum, reliable, sets, stats: ex.stats })
}

/// A client lane on the driver side — the event loop's lane shape behind a
/// socket: single-entry mailboxes around a poll-able state machine.
struct DriverLane<'m> {
    sm: ClientSm<'m>,
    inbox: Option<Down>,
    outbox: Option<Up>,
}

/// Drive `cfg.n` honest clients against a round server at `addr`.
///
/// Clients are built from the same [`derive_round_setup`] recipe as every
/// other executor and stepped in parallel sweeps over a worker pool; the
/// socket side is deliberately simple — blocking reads in id order, one
/// frame per live connection per sweep — because the server's phase
/// barrier already serializes the round globally.
pub fn drive_clients(
    addr: SocketAddr,
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    round: u32,
    timeout: Duration,
) -> Result<()> {
    assert_eq!(models.len(), cfg.n);
    let deadline = Instant::now() + timeout;
    let setup = derive_round_setup(cfg, models);
    let workers = event_loop_workers(cfg.n);
    let mask_workers = (crate::par::threads() / workers).max(1);
    let mut lanes: Vec<DriverLane<'_>> = crate::par::map_indexed(cfg.n, workers, |id| {
        let (mut key_rng, share_rng) = setup.streams[id].clone();
        let mut sm = ClientSm::new(
            id,
            cfg.t,
            cfg.mask_bits,
            setup.graph.neighbors(id).to_vec(),
            &mut key_rng,
            share_rng,
            &models[id],
            setup.plan.clone(),
            setup.survives[id],
        );
        sm.set_mask_workers(mask_workers);
        // unlike the in-process lanes, Down::Start arrives over the wire
        DriverLane { sm, inbox: None, outbox: None }
    });

    let mut conns: Vec<Option<TcpStream>> = Vec::with_capacity(cfg.n);
    for id in 0..cfg.n {
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    if Instant::now() >= deadline {
                        bail!("client {id}: connect to {addr} failed: {e}");
                    }
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        };
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(timeout)).context("set_read_timeout")?;
        conns.push(Some(stream));
    }

    let mut mid_round_close = false;
    loop {
        // read exactly one frame per live connection (blocking, id order)
        let mut any_open = false;
        for id in 0..cfg.n {
            let Some(stream) = conns[id].as_mut() else { continue };
            any_open = true;
            match wire::read_frame(stream) {
                Ok(Some(body)) => {
                    let (r, down) = wire::decode_down(&body)
                        .with_context(|| format!("client {id}: bad frame from server"))?;
                    if r != round {
                        bail!("client {id}: server frame tagged round {r}, expected {round}");
                    }
                    if matches!(down, Down::Finish) {
                        let _ = lanes[id].sm.step(Down::Finish);
                        conns[id] = None;
                    } else {
                        lanes[id].inbox = Some(down);
                    }
                }
                Ok(None) => {
                    // orderly close before Finish: the server aborted
                    if !lanes[id].sm.done() {
                        mid_round_close = true;
                    }
                    conns[id] = None;
                }
                Err(e) => {
                    if !lanes[id].sm.done() {
                        mid_round_close = true;
                    }
                    log::debug!("client {id}: read error: {e}");
                    conns[id] = None;
                }
            }
        }
        if !any_open {
            break;
        }
        if Instant::now() >= deadline {
            bail!("client driver timed out with connections still open");
        }

        // one parallel sweep: step every lane holding a phase input
        crate::par::for_each_slice(&mut lanes, workers, |_, chunk| {
            for lane in chunk.iter_mut() {
                if let Some(down) = lane.inbox.take() {
                    lane.outbox = Some(lane.sm.step(down));
                }
            }
        });

        // write answers in id order; a terminal answer ends our side
        for id in 0..cfg.n {
            let Some(up) = lanes[id].outbox.take() else { continue };
            let Some(stream) = conns[id].as_mut() else { continue };
            stream
                .write_all(&wire::encode_up(round, &up))
                .with_context(|| format!("client {id}: write failed"))?;
            if lanes[id].sm.done() {
                // Unmask / Dropped / Failed was this client's last word;
                // close so the server sees EOF once it pumped the frame
                conns[id] = None;
            }
        }
    }
    if mid_round_close {
        bail!("server closed a connection mid-round (round aborted)");
    }
    Ok(())
}

/// One full round over real loopback sockets: [`serve`] on a spawned
/// thread, [`drive_clients`] on the caller's, joined at the end. A server
/// error (including protocol aborts) takes precedence over the driver's.
pub fn run_round_wire(cfg: &ProtocolConfig, models: &[Vec<u64>]) -> Result<CoordRoundResult> {
    run_round_wire_with(cfg, models, DEFAULT_TIMEOUT)
}

/// [`run_round_wire`] with an explicit wall-clock budget.
pub fn run_round_wire_with(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    timeout: Duration,
) -> Result<CoordRoundResult> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("bind loopback")?;
    let addr = listener.local_addr().context("local_addr")?;
    let round = round_tag(cfg.seed);
    let setup = derive_round_setup(cfg, models);
    let plan = setup.plan.clone();
    let graph = setup.graph.clone();
    drop(setup);
    let srv_cfg = cfg.clone();
    let server =
        std::thread::spawn(move || serve(&listener, &srv_cfg, plan, graph, round, timeout));
    let drove = drive_clients(addr, cfg, models, round, timeout);
    let served = server.join().map_err(|_| anyhow::anyhow!("wire server thread panicked"))?;
    match (served, drove) {
        (Ok(result), Ok(())) => Ok(result),
        (Err(e), _) => Err(e.context("wire server")),
        (Ok(_), Err(e)) => Err(e.context("wire client driver")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::{engine, Topology};
    use crate::util::rng::Rng;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    #[test]
    fn round_tag_is_deterministic_in_the_seed() {
        assert_eq!(round_tag(41), round_tag(41));
        assert_eq!(round_tag(0), 0);
        assert_ne!(round_tag(41), round_tag(42));
        // high seed bits reach the tag
        assert_ne!(round_tag(1 << 40), round_tag(1 << 41));
    }

    #[test]
    fn tiny_round_over_loopback_matches_engine() {
        let n = 6;
        let dim = 8;
        let cfg = ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 99);
        let m = models(n, dim, 9);
        let wired = run_round_wire(&cfg, &m).unwrap();
        let sync = engine::run_round(&cfg, &m).unwrap();
        assert_eq!(wired.reliable, sync.reliable);
        assert_eq!(wired.sets, sync.sets);
        assert_eq!(wired.sum, sync.sum);
        assert!(wired.stats.logical_eq(&sync.stats), "wire logical stats differ from engine");
        let logical_up: u64 = sync.stats.bytes_up.iter().sum();
        let logical_down: u64 = sync.stats.bytes_down.iter().sum();
        assert!(wired.stats.framed_up > logical_up, "framing overhead must show up");
        assert!(wired.stats.framed_down > logical_down);
    }

    #[test]
    fn aborted_round_errors_on_both_sides_of_the_wire() {
        // every client drops at step 0 → |V1| = 0 < t: the server aborts,
        // drops the sockets, and the whole wire round reports Err — the
        // same observable shape as the engine and the event loop
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::for_test(n, 3, 4, Topology::Complete, 7)
        };
        let m = models(n, 4, 7);
        assert!(run_round_wire(&cfg, &m).is_err());
        assert!(engine::run_round(&cfg, &m).is_err());
    }
}
