//! Loopback-scale TCP transport for one aggregation round.
//!
//! `protocol::engine` and the `coordinator` event loop move [`Up`]/[`Down`]
//! values through memory; this module moves the same messages as
//! length-prefixed frames (`crate::wire`) over real sockets:
//!
//! * [`serve`] — the server side. Accepts `cfg.n` connections on a
//!   listener, then runs the four protocol phases as the event loop does:
//!   broadcast the phase's `Down` frames, poll every connection
//!   (nonblocking read/write sweeps) until each awaited client answered or
//!   died, decode and validate the `Up` frames, and hand them to
//!   [`Server`] in client-id order. Malformed frames close the offending
//!   connection; replayed or stale frames are discarded by phase — both
//!   without disturbing the round for honest clients. Knobs come from the
//!   shared [`RoundOptions`] surface: a journal directory makes every
//!   state transition fsync'd to a `crate::journal` round log before it
//!   takes effect, so the process can die at any point and
//!   [`serve_resume`] can finish the round from the log alone.
//! * [`serve_resume`] — replay a round journal into a live [`Server`] and
//!   pick the round up where the dead process stopped: re-accept the
//!   surviving clients, re-send the `Down`s they never received (clients
//!   resubmit their last `Up` on reconnect, which the server's first-wins
//!   dedupe makes idempotent), and run the remaining phases normally.
//! * [`drive_clients`] — the client side: n poll-able [`ClientSm`]s behind
//!   n blocking loopback sockets, stepped in parallel sweeps exactly like
//!   the event loop's lanes. Connect failures back off exponentially with
//!   deterministic jitter instead of failing the round.
//! * [`drive_clients_retry`] — the restart-tolerant client side: lanes
//!   keep the last `Up` frame they sent and, when the server dies
//!   mid-round, reconnect (to a freshly resolved address) and resubmit it;
//!   duplicate `Down`s re-delivered by a resumed server are answered from
//!   that cache without re-stepping the one-shot state machine.
//! * [`run_round_wire_opts`] — both halves wired together on an ephemeral
//!   loopback port; the shape `coordinator::RoundRunner` runs as the
//!   `wire` executor.
//! * [`run_warm_round_wire`] — the warm (session) variant: the server and
//!   the client state machines arrive pre-built from
//!   `protocol::session::Session`, phase 0 moves [`WarmResume`] /
//!   [`Down::WarmPlan`] frames instead of key advertisements, and both
//!   halves hand their state back so the session survives the round.
//!
//! Accounting: logical (Appendix-C) byte charges replicate the event loop
//! exactly — `Start`/`Finish` and `Dropped`/`Failed` cost nothing — so a
//! round over sockets is `NetStats::logical_eq` to the in-process engine.
//! On top of that, `framed_up`/`framed_down` count raw bytes as read from
//! and written to the sockets, framing overhead and duplicates included.
//! A resumed round's stats cover post-resume traffic only (the journal
//! records protocol state, not byte accounting).

use crate::codec::IndexPlan;
use crate::coordinator::{
    derive_round_setup, event_loop_workers, CoordRoundResult, RoundOptions, RoundTimeline,
    TimeoutPolicy,
};
use crate::graph::Graph;
use crate::journal::{self, Journal, JournalSink};
use crate::net::{Dir, NetStats};
use crate::protocol::client::ClientSm;
use crate::protocol::messages::*;
use crate::protocol::server::{RoundOutput, Server};
use crate::protocol::{ClientId, ProtocolConfig};
use crate::util::rng::Rng;
use crate::util::shutdown;
use crate::wire;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Default wall-clock budget for a whole round (accept + 4 phases).
pub const DEFAULT_TIMEOUT: Duration = Duration::from_secs(120);

/// Sleep between poll sweeps when nothing moved.
const POLL_PAUSE: Duration = Duration::from_micros(200);

/// The named prefix every "server died / was told to die mid-round" error
/// starts with. `ccesa serve --journal` exits nonzero with this message;
/// the round is finishable via [`serve_resume`].
pub const INTERRUPTED: &str = "round interrupted, resumable";

/// How long a phase-4 resume (the round already finalized on disk) keeps
/// accepting stragglers from the crashed attempt to wave them off with
/// `Finish` before returning the replayed output.
const RESUME_GRACE: Duration = Duration::from_millis(600);

/// First delay of the connect backoff schedule.
const BACKOFF_BASE: Duration = Duration::from_millis(1);

/// Ceiling of the connect backoff schedule.
const BACKOFF_CAP: Duration = Duration::from_millis(200);

/// The round tag stamped into every frame header, derived from the config
/// seed so both endpoints agree without negotiation.
pub fn round_tag(seed: u64) -> u32 {
    (seed ^ (seed >> 32)) as u32
}

/// Where a journaled server deliberately dies, for crash-injection tests:
/// after the named transition is journaled but before any of its output
/// frames are flushed to clients. Each variant is one row of the
/// crash-matrix in DESIGN.md §13.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StopAfter {
    /// Journal created (setup record on disk), all connections accepted,
    /// `Start` never sent.
    Setup,
    /// `apply_phase(p)` ran (its records are on disk, its `Down`s are
    /// queued) but nothing was flushed.
    Phase(u8),
}

/// Deterministic jittered exponential backoff between connect attempts,
/// seeded from the round tag and the client id so a replayed round sleeps
/// an identical schedule (satisfying the same determinism contract as the
/// protocol RNG streams).
struct Backoff {
    rng: Rng,
    cur: Duration,
}

impl Backoff {
    fn new(round: u32, id: ClientId) -> Backoff {
        let seed = ((round as u64) << 24) ^ (id as u64) ^ 0x00B0_0FF5;
        Backoff { rng: Rng::new(seed), cur: BACKOFF_BASE }
    }

    /// Next wait: half the current step plus uniform jitter over the other
    /// half, then double the step toward [`BACKOFF_CAP`].
    fn next_wait(&mut self) -> Duration {
        let us = self.cur.as_micros() as u64;
        let wait = Duration::from_micros(us / 2 + self.rng.gen_range((us / 2).max(1)));
        self.cur = (self.cur * 2).min(BACKOFF_CAP);
        wait
    }
}

/// One accepted connection: nonblocking stream plus reassembly and
/// write-behind buffers, and the per-phase conversation state.
struct Conn {
    stream: TcpStream,
    rx: wire::FrameBuffer,
    tx: Vec<u8>,
    tx_pos: usize,
    /// Claimed client id — set by the first valid phase-0 frame.
    id: Option<ClientId>,
    open: bool,
    /// The server delivered this phase's `Down` and expects exactly one
    /// `Up` back (the [`ClientSm::step`] contract).
    awaiting: bool,
    /// The phase answer, parked until the phase barrier harvests it.
    slot: Option<Up>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        Conn {
            stream,
            rx: wire::FrameBuffer::new(),
            tx: Vec::new(),
            tx_pos: 0,
            id: None,
            open: true,
            awaiting: false,
            slot: None,
        }
    }

    fn queue(&mut self, frame: &[u8]) {
        if self.open {
            self.tx.extend_from_slice(frame);
        }
    }

    /// Write as much buffered tx as the socket accepts right now; returns
    /// bytes written. Never blocks.
    fn flush(&mut self) -> usize {
        let mut written = 0;
        while self.open && self.tx_pos < self.tx.len() {
            match self.stream.write(&self.tx[self.tx_pos..]) {
                Ok(0) => self.close(),
                Ok(k) => {
                    self.tx_pos += k;
                    written += k;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log::debug!("write to client {:?} failed: {e}", self.id);
                    self.close();
                }
            }
        }
        if self.tx_pos == self.tx.len() {
            self.tx.clear();
            self.tx_pos = 0;
        }
        written
    }

    /// Nothing queued remains unwritten (either flushed or the peer died).
    fn drained(&self) -> bool {
        !self.open || self.tx_pos >= self.tx.len()
    }

    /// Drain the socket into the frame buffer; returns bytes read. Never
    /// blocks. EOF or a hard error closes the connection — frames already
    /// buffered are still decoded afterwards.
    fn pump(&mut self) -> usize {
        let mut total = 0;
        let mut tmp = [0u8; 16 * 1024];
        while self.open {
            match self.stream.read(&mut tmp) {
                Ok(0) => {
                    self.open = false;
                    self.awaiting = false;
                    break;
                }
                Ok(k) => {
                    self.rx.extend(&tmp[..k]);
                    total += k;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => {
                    log::debug!("read from client {:?} failed: {e}", self.id);
                    self.close();
                }
            }
        }
        total
    }

    fn close(&mut self) {
        self.open = false;
        self.awaiting = false;
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Decode buffered frames on one connection during the given phase.
///
/// A connection parks at most one `Up` per phase (`slot`); once it is
/// filled, further buffered frames wait — if they belong to this phase they
/// are duplicates and the next phase's sweep discards them by the
/// `Up::phase` check. Frame-level garbage closes the connection; a
/// mismatched round tag, a stale/replayed phase, or a spoofed sender id
/// only discards the frame, so one bad message never aborts the round for
/// honest clients.
fn drain_frames(
    c: &mut Conn,
    ci: usize,
    claimed: &mut [Option<usize>],
    plan: &Arc<IndexPlan>,
    round: u32,
    phase: u8,
) {
    while c.slot.is_none() {
        let body = match c.rx.next_frame() {
            Ok(Some(b)) => b,
            Ok(None) => return,
            Err(e) => {
                log::debug!("conn {ci}: bad frame ({e}); closing");
                c.close();
                return;
            }
        };
        let (r, up) = match wire::decode_up(&body, plan) {
            Ok(v) => v,
            Err(e) => {
                log::debug!("conn {ci}: undecodable message ({e}); closing");
                c.close();
                return;
            }
        };
        if r != round {
            log::debug!("conn {ci}: frame tagged round {r}, serving {round}; discarded");
            continue;
        }
        if up.phase() != phase {
            log::debug!(
                "conn {ci}: discarding phase-{} message during phase {phase} (replay or stale)",
                up.phase()
            );
            continue;
        }
        let from = up.from();
        match c.id {
            None => {
                // the first valid frame claims the connection's client id
                if from >= claimed.len() {
                    log::debug!("conn {ci}: claims out-of-range id {from}; closing");
                    c.close();
                    return;
                }
                if claimed[from].is_some() {
                    log::debug!("conn {ci}: id {from} already claimed; closing");
                    c.close();
                    return;
                }
                claimed[from] = Some(ci);
                c.id = Some(from);
            }
            Some(id) if id != from => {
                log::debug!("conn {ci} (client {id}): spoofed sender {from}; discarded");
                continue;
            }
            Some(_) => {}
        }
        c.slot = Some(up);
        c.awaiting = false;
    }
}

/// The server side of one round: connections, the id → connection claim
/// table, and the accumulating byte accounting.
struct Exchange {
    conns: Vec<Conn>,
    claimed: Vec<Option<usize>>,
    stats: NetStats,
    plan: Arc<IndexPlan>,
    round: u32,
    deadline: Instant,
    /// Per-recipient union-coordinate-map bytes riding on each warm plan
    /// down (TopK warm rounds only; 0 for cold rounds).
    map_bytes: usize,
    /// Per-phase straggler policy: the sim-tuned [`TimeoutPolicy`] mapped
    /// onto wall-clock poll deadlines. `None` → only the whole-round
    /// `deadline` applies (the historical behavior).
    policy: Option<TimeoutPolicy>,
    /// Wall-clock phase timings and per-phase timeout drops, mirrored from
    /// the event loop's virtual timeline so deployments report the same
    /// observable.
    timeline: RoundTimeline,
}

impl Exchange {
    /// Encode one `Down` and queue it for the connection claiming `id`,
    /// marking it awaited. The caller charges logical stats separately
    /// (unconditionally, for parity with the in-process executors).
    fn send(&mut self, id: ClientId, down: &Down) {
        self.send_frame(id, &wire::encode_down(self.round, down));
    }

    fn send_frame(&mut self, id: ClientId, frame: &[u8]) {
        match self.claimed.get(id).copied().flatten() {
            Some(ci) if self.conns[ci].open => {
                self.conns[ci].queue(frame);
                self.conns[ci].awaiting = true;
            }
            _ => log::debug!("no live connection claims client {id}; down frame dropped"),
        }
    }

    /// One phase barrier: flush pending writes, pump open connections,
    /// decode awaited answers, and return once no open connection is still
    /// awaited. Yields the parked `Up`s sorted by sender id — the same
    /// order the event loop drains its lanes in.
    ///
    /// With a [`TimeoutPolicy`], the phase additionally closes at
    /// `phase-open + per_phase_deadlines[phase]` (capped by the whole-round
    /// `deadline`): clients still outstanding then are disconnected and
    /// counted as timeout drops — from here on the round treats them
    /// exactly like churned clients — unless fewer than `min_survivors`
    /// answers have landed, in which case the server keeps waiting (up to
    /// the whole-round deadline, whose hard failure is unchanged).
    fn collect(&mut self, phase: u8) -> Result<Vec<Up>> {
        let deadline = self.deadline;
        let opened = Instant::now();
        let phase_deadline = self
            .policy
            .as_ref()
            .map(|p| (opened + p.per_phase_deadlines[phase as usize]).min(deadline));
        loop {
            if shutdown::requested() {
                bail!("{INTERRUPTED}: shutdown requested during phase {phase}");
            }
            let mut outstanding = 0;
            let Exchange { conns, claimed, stats, plan, round, .. } = self;
            for (ci, c) in conns.iter_mut().enumerate() {
                let written = c.flush();
                if written > 0 {
                    stats.record_framed(Dir::Down, written);
                }
                if c.open {
                    let read = c.pump();
                    if read > 0 {
                        stats.record_framed(Dir::Up, read);
                    }
                    if c.awaiting {
                        drain_frames(c, ci, claimed, plan, *round, phase);
                    }
                }
                if c.open && c.awaiting {
                    outstanding += 1;
                }
            }
            if outstanding == 0 {
                break;
            }
            if let Some(pd) = phase_deadline {
                let floor = self.policy.as_ref().map_or(0, |p| p.min_survivors);
                let delivered = self.conns.iter().filter(|c| c.slot.is_some()).count();
                if Instant::now() >= pd && delivered >= floor {
                    for c in self.conns.iter_mut() {
                        if c.open && c.awaiting {
                            if let Some(id) = c.id {
                                self.timeline.dropped[phase as usize].push(id);
                            }
                            c.close();
                            c.awaiting = false;
                            self.stats.record_timeout_drop(phase as usize);
                        }
                    }
                    self.timeline.dropped[phase as usize].sort_unstable();
                    break;
                }
            }
            if Instant::now() >= deadline {
                bail!("phase {phase}: timed out with {outstanding} clients still outstanding");
            }
            std::thread::sleep(POLL_PAUSE);
        }
        self.timeline.phase_elapsed_us[phase as usize] =
            opened.elapsed().as_micros().min(u64::MAX as u128) as u64;
        let mut ups: Vec<Up> = self.conns.iter_mut().filter_map(|c| c.slot.take()).collect();
        ups.sort_by_key(|u| u.from());
        Ok(ups)
    }
}

/// Route one phase's collected `Up`s into the server and queue the
/// resulting `Down`s, charging logical byte stats exactly as the event
/// loop does. Returns the round output after phase 3, `None` before.
///
/// Shared by [`serve`] / [`serve_warm`] (phases 0–3 in sequence) and
/// [`serve_resume`] (the remaining phases after replay) so the paths
/// cannot drift.
fn apply_phase(
    server: &mut Server,
    ex: &mut Exchange,
    phase: u8,
    ups: Vec<Up>,
) -> Result<Option<RoundOutput>> {
    match phase {
        0 if server.warm().is_some() => {
            let mut resumes = Vec::new();
            for up in ups {
                match up {
                    Up::Warm(r) => {
                        ex.stats.record(0, Dir::Up, r.id, r.size_bytes());
                        ex.stats.record_coord_map(r.support_bytes());
                        ex.stats.record_rekey(Dir::Up, r.rekey_bytes());
                        resumes.push(r);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    other => bail!("protocol order violation in warm phase 0: {other:?}"),
                }
            }
            let plans = server.warm_step0_resume(resumes)?;
            for (id, wp) in plans {
                ex.stats.record(0, Dir::Down, id, wp.size_bytes() + ex.map_bytes);
                ex.stats.record_coord_map(ex.map_bytes);
                ex.stats.record_rekey(Dir::Down, wp.rekey_bytes());
                ex.send(id, &Down::WarmPlan(wp));
            }
            Ok(None)
        }
        0 => {
            let mut advs = Vec::new();
            for up in ups {
                match up {
                    Up::Adv(a) => {
                        ex.stats.record(0, Dir::Up, a.id, a.size_bytes());
                        advs.push(a);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    other => bail!("protocol order violation in phase 0: {other:?}"),
                }
            }
            let bundles = server.step0_route_keys(advs)?;
            for (id, b) in bundles {
                ex.stats.record(0, Dir::Down, id, b.size_bytes());
                ex.send(id, &Down::Bundle(b));
            }
            Ok(None)
        }
        1 => {
            let mut uploads = Vec::new();
            for up in ups {
                match up {
                    Up::Shares(u) => {
                        ex.stats.record(1, Dir::Up, u.from, u.size_bytes());
                        uploads.push(u);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} withdrew step {step}: {e}"),
                    other => bail!("protocol order violation in phase 1: {other:?}"),
                }
            }
            let deliveries = server.step1_route_shares(uploads)?;
            for (id, d) in deliveries {
                ex.stats.record(1, Dir::Down, id, d.size_bytes());
                ex.send(id, &Down::Delivery(d));
            }
            Ok(None)
        }
        2 => {
            let mut masked = Vec::new();
            for up in ups {
                match up {
                    Up::Masked(m) => {
                        ex.stats.record(2, Dir::Up, m.id, m.size_bytes());
                        ex.stats.record_masked_payload(m.payload_bytes());
                        masked.push(m);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    other => bail!("protocol order violation in phase 2: {other:?}"),
                }
            }
            let announce = Arc::new(server.step2_collect_masked(masked)?);
            // one broadcast: encode once, queue the same frame per V3 member
            let frame = wire::encode_down(ex.round, &Down::Announce(announce.clone()));
            for &id in &announce.v3 {
                ex.stats.record(2, Dir::Down, id, announce.size_bytes());
                ex.send_frame(id, &frame);
            }
            Ok(None)
        }
        3 => {
            let mut responses = Vec::new();
            for up in ups {
                match up {
                    Up::Unmask(u) => {
                        ex.stats.record(3, Dir::Up, u.from, u.size_bytes());
                        responses.push(u);
                    }
                    Up::Dropped(id, step) => log::trace!("client {id} dropped at step {step}"),
                    Up::Failed(id, step, e) => log::debug!("client {id} failed step {step}: {e}"),
                    other => bail!("protocol order violation in phase 3: {other:?}"),
                }
            }
            Ok(Some(server.finalize(responses)?))
        }
        _ => bail!("apply_phase called with out-of-range phase {phase}"),
    }
}

/// Accept exactly `n` connections (nonblocking poll against `deadline`).
fn accept_exact(listener: &TcpListener, n: usize, deadline: Instant) -> Result<Vec<Conn>> {
    listener.set_nonblocking(true).context("set_nonblocking on listener")?;
    let mut conns = Vec::with_capacity(n);
    while conns.len() < n {
        if shutdown::requested() {
            bail!("{INTERRUPTED}: shutdown requested while accepting connections");
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = stream.set_nodelay(true);
                stream.set_nonblocking(true).context("set_nonblocking on accepted stream")?;
                conns.push(Conn::new(stream));
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                if Instant::now() >= deadline {
                    bail!("accepted {} of {n} connections before timeout", conns.len());
                }
                std::thread::sleep(POLL_PAUSE);
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e).context("accept"),
        }
    }
    Ok(conns)
}

/// Round over: tell anyone still connected, then flush best-effort.
/// V3 clients close after their Unmask, so this usually reaches nobody.
fn finish_blast(ex: &mut Exchange) {
    let fin = wire::encode_down(ex.round, &Down::Finish);
    for c in ex.conns.iter_mut() {
        if c.open {
            c.queue(&fin);
        }
    }
    let grace = Instant::now() + Duration::from_millis(250);
    loop {
        let mut pending = false;
        for c in ex.conns.iter_mut() {
            let written = c.flush();
            if written > 0 {
                ex.stats.record_framed(Dir::Down, written);
            }
            pending |= c.open && c.tx_pos < c.tx.len();
        }
        if !pending || Instant::now() >= grace {
            break;
        }
        std::thread::sleep(POLL_PAUSE);
    }
}

/// Serve one cold aggregation round to `cfg.n` socket clients.
///
/// `plan` and `graph` must come from the round's [`derive_round_setup`] so
/// the server validates incoming `Masked` frames against the same index
/// plan the clients encode with. Aborts (|V_k| < t) propagate as `Err`
/// after the connections are dropped, which the honest driver observes as
/// mid-round EOF — both sides fail, matching the engine's abort shape.
///
/// Knobs ride on [`RoundOptions`] (the executor field is not consulted —
/// this *is* the wire executor): `journal_dir` makes every state
/// transition fsync'd before it takes effect (crash recovery via
/// [`serve_resume`]); `stop_after` injects a deliberate crash for tests.
pub fn serve(
    listener: &TcpListener,
    cfg: &ProtocolConfig,
    plan: Arc<IndexPlan>,
    graph: Graph,
    round: u32,
    opts: &RoundOptions,
) -> Result<CoordRoundResult> {
    let deadline = Instant::now() + opts.timeout_or_default();
    // The journal's setup record goes to disk before the first client is
    // even accepted: a crash anywhere after this line leaves a resumable
    // round on disk.
    let mut server = Server::new(cfg.n, cfg.t, cfg.mask_bits, plan.clone(), graph.clone());
    if let Some(dir) = &opts.journal_dir {
        let j = Journal::create(dir, round, cfg.n, cfg.t, cfg.mask_bits, &plan, &graph)
            .context("create round journal")?;
        server.set_sink(Box::new(JournalSink::new(j)));
    }
    serve_accepted(listener, server, cfg.n, 0, round, deadline, opts)
}

/// Serve one warm (session) round to `expect` resuming session members.
///
/// The server arrives pre-built by `protocol::session::Session` (graph,
/// advertised keys and delta clocks loaded); phase 0 runs the
/// [`WarmResume`] / [`Down::WarmPlan`] exchange instead of key
/// advertisement. `map_bytes` is the per-recipient union-coordinate-map
/// charge riding on each plan down (TopK rounds).
pub(crate) fn serve_warm(
    listener: &TcpListener,
    mut server: Server,
    expect: usize,
    map_bytes: usize,
    round: u32,
    opts: &RoundOptions,
) -> (Result<CoordRoundResult>, Server) {
    debug_assert!(server.warm().is_some(), "serve_warm needs a warm server");
    let deadline = Instant::now() + opts.timeout_or_default();
    if let Some(dir) = &opts.journal_dir {
        let warm = server.warm().expect("warm server carries its context").clone();
        let made = Journal::create_warm(
            dir,
            round,
            server.n(),
            server.t(),
            server.mask_bits(),
            server.plan(),
            server.graph(),
            server.advertised_keys(),
            &warm,
            map_bytes,
        )
        .context("create warm round journal");
        match made {
            Ok(j) => server.set_sink(Box::new(JournalSink::new(j))),
            Err(e) => return (Err(e), server),
        }
    }
    let res = serve_accepted(listener, &mut server, expect, map_bytes, round, deadline, opts);
    (res, server)
}

/// The accept + Start + 4-phase loop shared by [`serve`] and
/// [`serve_warm`]: [`apply_phase`] branches on `server.warm()` so the two
/// paths cannot drift anywhere past phase 0.
fn serve_accepted(
    listener: &TcpListener,
    mut server: impl std::borrow::BorrowMut<Server>,
    expect: usize,
    map_bytes: usize,
    round: u32,
    deadline: Instant,
    opts: &RoundOptions,
) -> Result<CoordRoundResult> {
    let server = server.borrow_mut();
    let conns = accept_exact(listener, expect, deadline)?;
    let mut ex = Exchange {
        conns,
        claimed: vec![None; server.n()],
        stats: NetStats::new(server.n()),
        plan: server.plan().clone(),
        round,
        deadline,
        map_bytes,
        policy: opts.timeout_policy.clone(),
        timeline: RoundTimeline::default(),
    };

    if matches!(opts.stop_after, Some(StopAfter::Setup)) {
        bail!("{INTERRUPTED}: stopped after setup, before Start");
    }

    // phase 0 kickoff: Start itself carries no logical bytes
    let start = wire::encode_down(round, &Down::Start);
    for c in ex.conns.iter_mut() {
        c.queue(&start);
        c.awaiting = true;
    }

    let mut output = None;
    for phase in 0..4u8 {
        let ups = ex.collect(phase)?;
        output = apply_phase(server, &mut ex, phase, ups)?;
        if matches!(opts.stop_after, Some(StopAfter::Phase(p)) if p == phase) {
            // die with this phase journaled but none of its downs flushed
            bail!("{INTERRUPTED}: stopped after applying phase {phase}");
        }
    }
    let RoundOutput { sum, reliable, sets } = output.expect("phase 3 yields the round output");
    finish_blast(&mut ex);
    let timeline = ex.policy.is_some().then(|| ex.timeline.clone());
    Ok(CoordRoundResult { sum, reliable, sets, stats: ex.stats, timeline })
}

/// Resume a journaled round after a server crash or shutdown.
///
/// Replays `journal_path` into a bit-identical [`Server`], then runs the
/// reconnect barrier: every client owed the next phase's `Down` must
/// reconnect and resubmit its last `Up` (how the retry driver behaves),
/// which identifies it; it is re-sent the `Down` it never received and the
/// round proceeds through the remaining phases exactly as [`serve`]
/// would. Clients the round no longer needs are waved off with `Finish`.
/// A warm round's journal recovers to a warm [`Server`] (session caches
/// re-derived from the SETUP record), so mid-session rounds resume the
/// same way cold ones do.
///
/// Known limitation (documented in DESIGN.md §13): a client that already
/// sent its terminal `Up` and hung up cannot be re-asked, so a crash that
/// loses an unjournaled `Up` after the client disconnected stalls the
/// barrier to its deadline. The journal fsyncs before downs are flushed,
/// so the server never *acknowledges* state it could lose.
pub fn serve_resume(
    listener: &TcpListener,
    journal_path: &Path,
    opts: &RoundOptions,
) -> Result<CoordRoundResult> {
    let deadline = Instant::now() + opts.timeout_or_default();
    let rec = journal::recover(journal_path).context("recover round journal")?;
    let round = rec.round;
    let next = rec.next_phase;
    let mut server = rec.server;
    server.set_sink(Box::new(JournalSink::new(rec.journal)));
    listener.set_nonblocking(true).context("set_nonblocking on listener")?;

    let mut ex = Exchange {
        conns: Vec::new(),
        claimed: vec![None; rec.n],
        stats: NetStats::new(rec.n),
        plan: rec.plan.clone(),
        round,
        deadline,
        map_bytes: rec.map_bytes,
        policy: opts.timeout_policy.clone(),
        timeline: RoundTimeline::default(),
    };

    // The round already finalized on disk: nothing left to compute. Wave
    // off stragglers from the crashed attempt and return the replay.
    if next >= 4 {
        let output = rec.output.expect("phase-4 recovery carries the round output");
        finish_wave(listener, &mut ex)?;
        let RoundOutput { sum, reliable, sets } = output;
        return Ok(CoordRoundResult { sum, reliable, sets, stats: ex.stats, timeline: None });
    }

    if next == 0 {
        // Nobody ever saw Start: accept everyone and run from the top
        // (the recovered server state is empty, only the setup existed).
        ex.conns = accept_exact(listener, rec.n, deadline)?;
        let start = wire::encode_down(round, &Down::Start);
        for c in ex.conns.iter_mut() {
            c.queue(&start);
            c.awaiting = true;
        }
    } else {
        resume_barrier(listener, &mut ex, &rec.downs, next)?;
    }

    let mut output = None;
    for phase in next..4 {
        let ups = ex.collect(phase)?;
        output = apply_phase(&mut server, &mut ex, phase, ups)?;
    }
    let RoundOutput { sum, reliable, sets } = output.expect("phase 3 yields the round output");
    finish_blast(&mut ex);
    let timeline = ex.policy.is_some().then(|| ex.timeline.clone());
    Ok(CoordRoundResult { sum, reliable, sets, stats: ex.stats, timeline })
}

/// The reconnect barrier of a mid-round resume: accept connections and
/// classify each by its first valid frame until every `Down`-recipient of
/// `phase` has been re-sent its down (or already answered it).
///
/// Claiming rules, for a client owed a down: a frame from `phase` itself
/// parks as that client's answer (the pre-crash flush reached it); a frame
/// from `phase - 1` is the resubmitted previous answer — the client never
/// saw its down, so it is re-sent and awaited. Anything else (a client the
/// round no longer needs, or one too far behind to rejoin) is told
/// `Finish` and forgotten.
fn resume_barrier(
    listener: &TcpListener,
    ex: &mut Exchange,
    downs: &[(ClientId, Down)],
    phase: u8,
) -> Result<()> {
    let finish = wire::encode_down(ex.round, &Down::Finish);
    let mut owed: BTreeMap<ClientId, Vec<u8>> =
        downs.iter().map(|(id, d)| (*id, wire::encode_down(ex.round, d))).collect();
    let total = owed.len();
    while !owed.is_empty() {
        if shutdown::requested() {
            bail!("{INTERRUPTED}: shutdown requested during the resume barrier");
        }
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    stream.set_nonblocking(true).context("set_nonblocking on accepted stream")?;
                    ex.conns.push(Conn::new(stream));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("accept during resume"),
            }
        }
        for ci in 0..ex.conns.len() {
            let written = ex.conns[ci].flush();
            if written > 0 {
                ex.stats.record_framed(Dir::Down, written);
            }
            if !ex.conns[ci].open || ex.conns[ci].id.is_some() {
                continue;
            }
            let read = ex.conns[ci].pump();
            if read > 0 {
                ex.stats.record_framed(Dir::Up, read);
            }
            // classify this connection by its first valid frame
            loop {
                let c = &mut ex.conns[ci];
                let body = match c.rx.next_frame() {
                    Ok(Some(b)) => b,
                    Ok(None) => break,
                    Err(e) => {
                        log::debug!("resume conn {ci}: bad frame ({e}); closing");
                        c.close();
                        break;
                    }
                };
                let (r, up) = match wire::decode_up(&body, &ex.plan) {
                    Ok(v) => v,
                    Err(e) => {
                        log::debug!("resume conn {ci}: undecodable message ({e}); closing");
                        c.close();
                        break;
                    }
                };
                if r != ex.round {
                    continue;
                }
                let from = up.from();
                if from >= ex.claimed.len() || ex.claimed[from].is_some() {
                    log::debug!("resume conn {ci}: invalid or duplicate claim of id {from}");
                    c.close();
                    break;
                }
                ex.claimed[from] = Some(ci);
                c.id = Some(from);
                match owed.remove(&from) {
                    Some(frame) => {
                        if up.phase() == phase {
                            // the pre-crash flush reached this client and
                            // this is already its next answer
                            c.slot = Some(up);
                            c.awaiting = false;
                        } else if up.phase() + 1 == phase {
                            c.queue(&frame);
                            c.awaiting = true;
                        } else {
                            log::debug!(
                                "resume: client {from} resubmitted phase {}, serving {phase}; \
                                 too far behind to rejoin",
                                up.phase()
                            );
                            c.queue(&finish);
                        }
                    }
                    None => c.queue(&finish),
                }
                break;
            }
        }
        if owed.is_empty() {
            break;
        }
        if Instant::now() >= ex.deadline {
            bail!(
                "resume barrier: timed out with {} of {total} expected clients not back",
                owed.len()
            );
        }
        std::thread::sleep(POLL_PAUSE);
    }
    Ok(())
}

/// Phase-4 resume: the round is already finalized, so every reconnecting
/// client is a straggler from the crashed attempt — accept it, read off
/// its resubmission, and wave it away with `Finish` for a grace window.
fn finish_wave(listener: &TcpListener, ex: &mut Exchange) -> Result<()> {
    let finish = wire::encode_down(ex.round, &Down::Finish);
    let until = Instant::now() + RESUME_GRACE;
    while Instant::now() < until {
        if shutdown::requested() {
            break;
        }
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    let _ = stream.set_nodelay(true);
                    stream.set_nonblocking(true).context("set_nonblocking on accepted stream")?;
                    let mut c = Conn::new(stream);
                    c.queue(&finish);
                    ex.conns.push(c);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e).context("accept during finish wave"),
            }
        }
        for c in ex.conns.iter_mut() {
            let written = c.flush();
            if written > 0 {
                ex.stats.record_framed(Dir::Down, written);
            }
            if c.open {
                let read = c.pump();
                if read > 0 {
                    ex.stats.record_framed(Dir::Up, read);
                }
            }
        }
        std::thread::sleep(POLL_PAUSE);
    }
    Ok(())
}

/// A client lane on the driver side — the event loop's lane shape behind a
/// socket: single-entry mailboxes around a poll-able state machine.
struct DriverLane<'m> {
    sm: ClientSm<'m>,
    inbox: Option<Down>,
    outbox: Option<Up>,
}

/// Build the driver-side lanes from the round's canonical setup recipe.
fn build_lanes<'m>(
    cfg: &ProtocolConfig,
    models: &'m [Vec<u64>],
    workers: usize,
) -> Vec<DriverLane<'m>> {
    let setup = derive_round_setup(cfg, models);
    let mask_workers = (crate::par::threads() / workers).max(1);
    crate::par::map_indexed(cfg.n, workers, |id| {
        let (mut key_rng, share_rng) = setup.streams[id].clone();
        let mut sm = ClientSm::new(
            id,
            cfg.t,
            cfg.mask_bits,
            setup.graph.neighbors(id).to_vec(),
            &mut key_rng,
            share_rng,
            &models[id],
            setup.plan.clone(),
            setup.survives[id],
        );
        sm.set_mask_workers(mask_workers);
        // unlike the in-process lanes, Down::Start arrives over the wire
        DriverLane { sm, inbox: None, outbox: None }
    })
}

/// Drive `cfg.n` honest clients against a round server at `addr`.
///
/// Clients are built from the same [`derive_round_setup`] recipe as every
/// other executor and stepped in parallel sweeps over a worker pool; the
/// socket side is deliberately simple — blocking reads in id order, one
/// frame per live connection per sweep — because the server's phase
/// barrier already serializes the round globally. A refused connect is
/// retried under deterministic jittered backoff until the deadline, not
/// surfaced as a round failure.
pub fn drive_clients(
    addr: SocketAddr,
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    round: u32,
    timeout: Duration,
) -> Result<()> {
    assert_eq!(models.len(), cfg.n);
    let workers = event_loop_workers(cfg.n);
    let mut lanes = build_lanes(cfg, models, workers);
    drive_lanes(addr, &mut lanes, round, timeout, workers)
}

/// The body of [`drive_clients`], factored over pre-built lanes so the
/// warm wire round can drive a session's resumed state machines through
/// the identical sweep loop. Lane order need not match client ids — each
/// lane owns its socket and the server claims identities from frames.
fn drive_lanes(
    addr: SocketAddr,
    lanes: &mut [DriverLane<'_>],
    round: u32,
    timeout: Duration,
    workers: usize,
) -> Result<()> {
    let deadline = Instant::now() + timeout;
    let n = lanes.len();
    let mut conns: Vec<Option<TcpStream>> = Vec::with_capacity(n);
    for id in 0..n {
        let mut backoff = Backoff::new(round, id);
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    let now = Instant::now();
                    if now >= deadline {
                        bail!("client lane {id}: connect to {addr} failed: {e}");
                    }
                    std::thread::sleep(backoff.next_wait().min(deadline - now));
                }
            }
        };
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(Some(timeout)).context("set_read_timeout")?;
        conns.push(Some(stream));
    }

    let mut mid_round_close = false;
    loop {
        // read exactly one frame per live connection (blocking, id order)
        let mut any_open = false;
        for id in 0..n {
            let Some(stream) = conns[id].as_mut() else { continue };
            any_open = true;
            match wire::read_frame(stream) {
                Ok(Some(body)) => {
                    let (r, down) = wire::decode_down(&body)
                        .with_context(|| format!("client {id}: bad frame from server"))?;
                    if r != round {
                        bail!("client {id}: server frame tagged round {r}, expected {round}");
                    }
                    if matches!(down, Down::Finish) {
                        let _ = lanes[id].sm.step(Down::Finish);
                        conns[id] = None;
                    } else {
                        lanes[id].inbox = Some(down);
                    }
                }
                Ok(None) => {
                    // orderly close before Finish: the server aborted
                    if !lanes[id].sm.done() {
                        mid_round_close = true;
                    }
                    conns[id] = None;
                }
                Err(e) => {
                    if !lanes[id].sm.done() {
                        mid_round_close = true;
                    }
                    log::debug!("client {id}: read error: {e}");
                    conns[id] = None;
                }
            }
        }
        if !any_open {
            break;
        }
        if Instant::now() >= deadline {
            bail!("client driver timed out with connections still open");
        }

        // one parallel sweep: step every lane holding a phase input
        crate::par::for_each_slice(lanes, workers, |_, chunk| {
            for lane in chunk.iter_mut() {
                if let Some(down) = lane.inbox.take() {
                    lane.outbox = Some(lane.sm.step(down));
                }
            }
        });

        // write answers in id order; a terminal answer ends our side
        for id in 0..n {
            let Some(up) = lanes[id].outbox.take() else { continue };
            let Some(stream) = conns[id].as_mut() else { continue };
            stream
                .write_all(&wire::encode_up(round, &up))
                .with_context(|| format!("client lane {id}: write failed"))?;
            if lanes[id].sm.done() {
                // Unmask / Dropped / Failed was this client's last word;
                // close so the server sees EOF once it pumped the frame
                conns[id] = None;
            }
        }
    }
    if mid_round_close {
        bail!("server closed a connection mid-round (round aborted)");
    }
    Ok(())
}

/// Per-lane socket state of the restart-tolerant driver: a nonblocking
/// connection plus the cached wire frame of the lane's last answer.
struct RetryLink {
    conn: Option<Conn>,
    backoff: Backoff,
    next_attempt: Instant,
    /// The encoded frame of the last `Up` this lane sent — resubmitted
    /// verbatim on every reconnect (claiming the lane's identity for the
    /// resume barrier) and re-sent on duplicate `Down`s. The server's
    /// first-wins dedupe makes both idempotent.
    last_up: Option<Vec<u8>>,
    /// The highest down-phase already stepped through the one-shot SM.
    answered: Option<u8>,
    /// The lane heard `Finish`, or had nothing more to say when the
    /// connection went away.
    done: bool,
}

/// Drive `cfg.n` honest clients against a server that may die and be
/// resumed (via [`serve_resume`]) any number of times mid-round.
///
/// Differences from [`drive_clients`]: connections are nonblocking with a
/// per-lane reassembly buffer; `resolve` is consulted on every reconnect
/// (a restarted server usually binds a fresh ephemeral port); a lane whose
/// connection dies before it is done reconnects under backoff and
/// resubmits its last `Up` frame; a duplicate `Down` (phase already
/// answered) is answered from the cached frame — the one-shot [`ClientSm`]
/// is never re-stepped. A lane that already said its last word treats EOF
/// as the round ending rather than reconnecting.
pub fn drive_clients_retry(
    mut resolve: impl FnMut() -> SocketAddr,
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    round: u32,
    timeout: Duration,
) -> Result<()> {
    assert_eq!(models.len(), cfg.n);
    let deadline = Instant::now() + timeout;
    let workers = event_loop_workers(cfg.n);
    let mut lanes = build_lanes(cfg, models, workers);
    let now = Instant::now();
    let mut links: Vec<RetryLink> = (0..cfg.n)
        .map(|id| RetryLink {
            conn: None,
            backoff: Backoff::new(round, id),
            next_attempt: now,
            last_up: None,
            answered: None,
            done: false,
        })
        .collect();

    loop {
        let mut moved = false;
        for id in 0..cfg.n {
            let link = &mut links[id];
            if link.done {
                // only a terminal answer may still be in flight
                if let Some(c) = link.conn.as_mut() {
                    c.flush();
                    if c.drained() {
                        c.close();
                        link.conn = None;
                    }
                }
                continue;
            }
            if link.conn.as_ref().map_or(true, |c| !c.open) {
                if lanes[id].sm.done() {
                    // last word sent and the connection is gone: nothing
                    // left to say, so do not chase a restarted server
                    link.conn = None;
                    link.done = true;
                    continue;
                }
                if Instant::now() < link.next_attempt {
                    continue;
                }
                match TcpStream::connect(resolve()) {
                    Ok(s) => {
                        let _ = s.set_nodelay(true);
                        s.set_nonblocking(true).context("set_nonblocking on client stream")?;
                        let mut c = Conn::new(s);
                        if let Some(f) = &link.last_up {
                            // resubmit: identifies the lane to a resumed
                            // server; first-wins dedupe drops it otherwise
                            c.queue(f);
                        }
                        link.conn = Some(c);
                        moved = true;
                    }
                    Err(_) => {
                        link.next_attempt = Instant::now() + link.backoff.next_wait();
                        continue;
                    }
                }
            }
            let c = link.conn.as_mut().expect("connected above");
            moved |= c.flush() > 0;
            moved |= c.pump() > 0;
            while lanes[id].inbox.is_none() && !link.done {
                let body = match c.rx.next_frame() {
                    Ok(Some(b)) => b,
                    Ok(None) => break,
                    Err(e) => {
                        log::debug!("client {id}: bad frame from server ({e}); reconnecting");
                        c.close();
                        break;
                    }
                };
                let (r, down) = wire::decode_down(&body)
                    .with_context(|| format!("client {id}: undecodable frame from server"))?;
                if r != round {
                    bail!("client {id}: server frame tagged round {r}, expected {round}");
                }
                let Some(dp) = down.phase() else {
                    let _ = lanes[id].sm.step(Down::Finish);
                    link.done = true;
                    c.close();
                    link.conn = None;
                    break;
                };
                let next = link.answered.map_or(0, |a| a + 1);
                if dp < next {
                    // a resumed server re-sent a down we already answered:
                    // answer from the cache, never re-step the one-shot SM
                    if let Some(f) = link.last_up.clone() {
                        c.queue(&f);
                        moved = true;
                    }
                    continue;
                }
                if dp > next {
                    bail!("client {id}: server skipped from phase {next} to {dp}");
                }
                link.answered = Some(dp);
                lanes[id].inbox = Some(down);
                moved = true;
                break;
            }
            if let Some(c) = link.conn.as_ref() {
                if !c.open && lanes[id].inbox.is_none() && !link.done {
                    // the server died mid-round; retry after a backoff
                    link.conn = None;
                    link.next_attempt = Instant::now() + link.backoff.next_wait();
                }
            }
        }

        // one parallel sweep: step every lane holding a phase input
        crate::par::for_each_slice(&mut lanes, workers, |_, chunk| {
            for lane in chunk.iter_mut() {
                if let Some(down) = lane.inbox.take() {
                    lane.outbox = Some(lane.sm.step(down));
                }
            }
        });

        // queue answers; cache each frame for resubmission on reconnect
        for id in 0..cfg.n {
            let Some(up) = lanes[id].outbox.take() else { continue };
            let frame = wire::encode_up(round, &up);
            let link = &mut links[id];
            if let Some(c) = link.conn.as_mut() {
                c.queue(&frame);
                moved = true;
            }
            link.last_up = Some(frame);
            // lanes that said their last word linger for Finish (or EOF):
            // a resumed server may still need the frame re-sent
        }

        if links.iter().all(|l| l.done && l.conn.is_none()) {
            return Ok(());
        }
        if Instant::now() >= deadline {
            let live = links.iter().filter(|l| !l.done).count();
            bail!("retry client driver timed out with {live} lanes unfinished");
        }
        if !moved {
            std::thread::sleep(POLL_PAUSE);
        }
    }
}

/// One full cold round over real loopback sockets: [`serve`] on a spawned
/// thread, [`drive_clients`] on the caller's, joined at the end. A server
/// error (including protocol aborts) takes precedence over the driver's.
/// This is the `wire` arm of `coordinator::RoundRunner`; journal and
/// crash-injection knobs on `opts` reach the serving half.
pub fn run_round_wire_opts(
    cfg: &ProtocolConfig,
    models: &[Vec<u64>],
    opts: &RoundOptions,
) -> Result<CoordRoundResult> {
    let listener = TcpListener::bind(("127.0.0.1", 0)).context("bind loopback")?;
    let addr = listener.local_addr().context("local_addr")?;
    let round = round_tag(cfg.seed);
    let timeout = opts.timeout_or_default();
    let setup = derive_round_setup(cfg, models);
    let plan = setup.plan.clone();
    let graph = setup.graph.clone();
    drop(setup);
    let (served, drove) = std::thread::scope(|s| {
        let handle = s.spawn(|| serve(&listener, cfg, plan, graph, round, opts));
        let drove = drive_clients(addr, cfg, models, round, timeout);
        let served =
            handle.join().map_err(|_| anyhow::anyhow!("wire server thread panicked"));
        (served, drove)
    });
    match (served?, drove) {
        (Ok(result), Ok(())) => Ok(result),
        (Err(e), _) => Err(e.context("wire server")),
        (Ok(_), Err(e)) => Err(e.context("wire client driver")),
    }
}

/// One warm (session) round over real loopback sockets: [`serve_warm`] on
/// a scoped thread, the session's resumed state machines driven through
/// [`drive_lanes`] on the caller's. Both halves hand their state back —
/// even on an abort — so `protocol::session::Session` re-seats its
/// clients and the session outlives the failed round.
pub(crate) fn run_warm_round_wire<'m>(
    server: Server,
    machines: Vec<ClientSm<'m>>,
    map_bytes: usize,
    round: u32,
    opts: &RoundOptions,
) -> (Result<CoordRoundResult>, Server, Vec<ClientSm<'m>>) {
    let listener = match TcpListener::bind(("127.0.0.1", 0)).context("bind loopback") {
        Ok(l) => l,
        Err(e) => return (Err(e), server, machines),
    };
    let addr = match listener.local_addr().context("local_addr") {
        Ok(a) => a,
        Err(e) => return (Err(e), server, machines),
    };
    let timeout = opts.timeout_or_default();
    let expect = machines.len();
    let mut lanes: Vec<DriverLane<'m>> =
        machines.into_iter().map(|sm| DriverLane { sm, inbox: None, outbox: None }).collect();
    let workers = event_loop_workers(expect);
    let (served, server, drove) = std::thread::scope(|s| {
        let handle = s.spawn(|| serve_warm(&listener, server, expect, map_bytes, round, opts));
        let drove = drive_lanes(addr, &mut lanes, round, timeout, workers);
        let (served, server) =
            handle.join().expect("warm wire server thread panicked");
        (served, server, drove)
    });
    let machines = lanes.into_iter().map(|l| l.sm).collect();
    let result = match (served, drove) {
        (Ok(result), Ok(())) => Ok(result),
        (Err(e), _) => Err(e.context("warm wire server")),
        (Ok(_), Err(e)) => Err(e.context("warm wire client driver")),
    };
    (result, server, machines)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::protocol::dropout::DropoutModel;
    use crate::protocol::{engine, Topology};
    use crate::util::rng::Rng;

    fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
            .collect()
    }

    #[test]
    fn round_tag_is_deterministic_in_the_seed() {
        assert_eq!(round_tag(41), round_tag(41));
        assert_eq!(round_tag(0), 0);
        assert_ne!(round_tag(41), round_tag(42));
        // high seed bits reach the tag
        assert_ne!(round_tag(1 << 40), round_tag(1 << 41));
    }

    #[test]
    fn backoff_is_deterministic_and_bounded() {
        let schedule = |round, id| {
            let mut b = Backoff::new(round, id);
            (0..12).map(|_| b.next_wait()).collect::<Vec<_>>()
        };
        assert_eq!(schedule(7, 3), schedule(7, 3), "same seed, same schedule");
        assert_ne!(schedule(7, 3), schedule(7, 4), "per-client jitter");
        assert_ne!(schedule(7, 3), schedule(8, 3), "per-round jitter");
        let s = schedule(7, 3);
        // every wait sits inside its doubling step's window, capped
        assert!(s.iter().all(|w| *w <= BACKOFF_CAP));
        assert!(s[0] >= BACKOFF_BASE / 2);
        // the tail reaches the cap's window
        assert!(s[11] >= BACKOFF_CAP / 2);
    }

    #[test]
    fn tiny_round_over_loopback_matches_engine() {
        let n = 6;
        let dim = 8;
        let cfg = ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 99);
        let m = models(n, dim, 9);
        let wired = run_round_wire_opts(&cfg, &m, &RoundOptions::default()).unwrap();
        let sync = engine::run_round(&cfg, &m).unwrap();
        assert_eq!(wired.reliable, sync.reliable);
        assert_eq!(wired.sets, sync.sets);
        assert_eq!(wired.sum, sync.sum);
        assert!(wired.stats.logical_eq(&sync.stats), "wire logical stats differ from engine");
        let logical_up: u64 = sync.stats.bytes_up.iter().sum();
        let logical_down: u64 = sync.stats.bytes_down.iter().sum();
        assert!(wired.stats.framed_up > logical_up, "framing overhead must show up");
        assert!(wired.stats.framed_down > logical_down);
    }

    #[test]
    fn retry_driver_matches_engine_on_an_uninterrupted_round() {
        // the restart-tolerant driver must be a drop-in replacement when
        // the server happens not to crash
        let n = 6;
        let dim = 8;
        let cfg = ProtocolConfig::for_test(n, 3, dim, Topology::Complete, 99);
        let m = models(n, dim, 9);
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let round = round_tag(cfg.seed);
        let setup = derive_round_setup(&cfg, &m);
        let (plan, graph) = (setup.plan.clone(), setup.graph.clone());
        let srv_cfg = cfg.clone();
        let server = std::thread::spawn(move || {
            serve(&listener, &srv_cfg, plan, graph, round, &RoundOptions::default())
        });
        drive_clients_retry(|| addr, &cfg, &m, round, DEFAULT_TIMEOUT).unwrap();
        let wired = server.join().unwrap().unwrap();
        let sync = engine::run_round(&cfg, &m).unwrap();
        assert_eq!(wired.sum, sync.sum);
        assert_eq!(wired.sets, sync.sets);
        assert!(wired.stats.logical_eq(&sync.stats));
    }

    #[test]
    fn aborted_round_errors_on_both_sides_of_the_wire() {
        // every client drops at step 0 → |V1| = 0 < t: the server aborts,
        // drops the sockets, and the whole wire round reports Err — the
        // same observable shape as the engine and the event loop
        let n = 5;
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Targeted {
                per_step: [(0..n).collect(), vec![], vec![], vec![]],
            },
            ..ProtocolConfig::for_test(n, 3, 4, Topology::Complete, 7)
        };
        let m = models(n, 4, 7);
        assert!(run_round_wire_opts(&cfg, &m, &RoundOptions::default()).is_err());
        assert!(engine::run_round(&cfg, &m).is_err());
    }
}
