//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration counts targeting a measurement
//! budget, and robust statistics (median / p95). All `cargo bench` targets
//! (`rust/benches/*.rs`, `harness = false`) use this module.
//!
//! ```no_run
//! use ccesa::bench::Bench;
//! let mut b = Bench::new("demo");
//! b.bench("hash 1KiB", || {
//!     // work under test
//! });
//! b.report();
//! ```

use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// One benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub summary: Summary, // per-iteration seconds
    pub throughput_label: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        self.summary.p50
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark group with a shared measurement budget per case.
pub struct Bench {
    pub group: String,
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // CCESA_BENCH_FAST=1 shrinks budgets (used by `make test` smoke).
        let fast = std::env::var("CCESA_BENCH_FAST").ok().as_deref() == Some("1");
        let mut b = Bench {
            group: group.to_string(),
            warmup: if fast { Duration::from_millis(20) } else { Duration::from_millis(200) },
            budget: if fast { Duration::from_millis(100) } else { Duration::from_secs(1) },
            min_iters: 5,
            max_iters: 1_000_000,
            results: Vec::new(),
        };
        // CCESA_BENCH_BUDGET_MS overrides the per-case budget, and shrinks
        // warmup/min_iters with it so the cap is real for expensive cases
        // (campaign benches at n≈1000 cost seconds per iteration). One
        // warmup iteration always runs — that is the calibration floor.
        if let Some(ms) =
            std::env::var("CCESA_BENCH_BUDGET_MS").ok().and_then(|v| v.parse::<u64>().ok())
        {
            b.budget = Duration::from_millis(ms);
            b.warmup = b.warmup.min(Duration::from_millis(ms / 4));
            b.min_iters = 1;
        }
        b
    }

    /// Benchmark a closure; returns median seconds per iteration.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        self.bench_with_throughput(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation, e.g. `(bytes as f64, "B/s")`
    /// or `(ops as f64, "elem/s")` per iteration.
    pub fn throughput(
        &mut self,
        name: &str,
        amount: f64,
        unit: &'static str,
        mut f: impl FnMut(),
    ) -> f64 {
        self.bench_with_throughput(name, Some((amount, unit)), &mut f)
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        thr: Option<(f64, &'static str)>,
        f: &mut dyn FnMut(),
    ) -> f64 {
        // Warmup + calibration: figure out per-iter cost.
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup || calib_iters == 0 {
            f();
            calib_iters += 1;
            if calib_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / calib_iters as f64;

        // Choose up to ~20 samples covering the budget; expensive cases
        // (per-iteration cost beyond the budget) degrade gracefully to
        // `min_iters` single-iteration samples instead of 20× overruns.
        let budget_s = self.budget.as_secs_f64();
        let samples = ((budget_s / per_iter).ceil() as u64).clamp(self.min_iters, 20);
        let iters_per_sample =
            ((budget_s / samples as f64 / per_iter).ceil() as u64).clamp(1, self.max_iters);
        let total_target = (samples * iters_per_sample).max(self.min_iters);

        let mut times = Vec::with_capacity(samples as usize);
        let mut done = 0u64;
        while done < total_target {
            let batch = iters_per_sample.min(total_target - done);
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / batch as f64);
            done += batch;
        }
        let summary = Summary::of(&times);
        let median = summary.p50;
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: done,
            summary,
            throughput_label: thr,
        });
        median
    }

    /// Print a formatted report for the group.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        let width = self.results.iter().map(|r| r.name.len()).max().unwrap_or(8).max(8);
        for r in &self.results {
            let med = r.summary.p50;
            let thr = r
                .throughput_label
                .map(|(amt, unit)| {
                    let rate = amt / med;
                    if unit.starts_with("B/") {
                        format!("  {:>9.1} MiB/s", rate / (1024.0 * 1024.0))
                    } else {
                        format!("  {rate:>12.0} {unit}")
                    }
                })
                .unwrap_or_default();
            println!(
                "  {:<width$}  med {:>11}  p95 {:>11}  (n={}){thr}",
                r.name,
                fmt_time(med),
                fmt_time(r.summary.p95),
                r.iters,
            );
        }
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("CCESA_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        let med = b.bench("add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(med > 0.0);
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters >= 5);
    }

    #[test]
    fn ordering_reflects_work() {
        std::env::set_var("CCESA_BENCH_FAST", "1");
        let mut b = Bench::new("order");
        let cheap = b.bench("cheap", || {
            black_box(1u64 + 1);
        });
        let pricey = b.bench("pricey", || {
            let mut s = 0u64;
            for i in 0..2000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(pricey > cheap, "pricey={pricey} cheap={cheap}");
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
