//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, adaptive iteration counts targeting a measurement
//! budget, and robust statistics (median / p95). All `cargo bench` targets
//! (`rust/benches/*.rs`, `harness = false`) use this module.
//!
//! ```no_run
//! use ccesa::bench::Bench;
//! let mut b = Bench::new("demo");
//! b.bench("hash 1KiB", || {
//!     // work under test
//! });
//! b.report();
//! ```

use crate::util::json::Json;
use crate::util::stats::Summary;
use std::time::{Duration, Instant};

/// One benchmark result row.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub summary: Summary, // per-iteration seconds
    pub throughput_label: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn median_s(&self) -> f64 {
        self.summary.p50
    }

    /// Machine-readable form of one result row.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("name", Json::str(&self.name)),
            ("iters", Json::Num(self.iters as f64)),
            ("median_s", Json::Num(self.summary.p50)),
            ("p95_s", Json::Num(self.summary.p95)),
            ("mean_s", Json::Num(self.summary.mean)),
            ("min_s", Json::Num(self.summary.min)),
            ("max_s", Json::Num(self.summary.max)),
        ];
        if let Some((amount, unit)) = self.throughput_label {
            fields.push(("throughput", Json::Num(amount / self.summary.p50)));
            fields.push(("throughput_unit", Json::str(unit)));
        }
        Json::obj(fields)
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark group with a shared measurement budget per case.
pub struct Bench {
    pub group: String,
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: u64,
    pub max_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // CCESA_BENCH_FAST=1 shrinks budgets (used by `make test` smoke).
        let fast = std::env::var("CCESA_BENCH_FAST").ok().as_deref() == Some("1");
        let mut b = Bench {
            group: group.to_string(),
            warmup: if fast { Duration::from_millis(20) } else { Duration::from_millis(200) },
            budget: if fast { Duration::from_millis(100) } else { Duration::from_secs(1) },
            min_iters: 5,
            max_iters: 1_000_000,
            results: Vec::new(),
        };
        // CCESA_BENCH_BUDGET_MS overrides the per-case budget, and shrinks
        // warmup/min_iters with it so the cap is real for expensive cases
        // (campaign benches at n≈1000 cost seconds per iteration). One
        // warmup iteration always runs — that is the calibration floor.
        if let Some(ms) =
            std::env::var("CCESA_BENCH_BUDGET_MS").ok().and_then(|v| v.parse::<u64>().ok())
        {
            b.budget = Duration::from_millis(ms);
            b.warmup = b.warmup.min(Duration::from_millis(ms / 4));
            b.min_iters = 1;
        }
        b
    }

    /// Benchmark a closure; returns median seconds per iteration.
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> f64 {
        self.bench_with_throughput(name, None, &mut f)
    }

    /// Benchmark with a throughput annotation, e.g. `(bytes as f64, "B/s")`
    /// or `(ops as f64, "elem/s")` per iteration.
    pub fn throughput(
        &mut self,
        name: &str,
        amount: f64,
        unit: &'static str,
        mut f: impl FnMut(),
    ) -> f64 {
        self.bench_with_throughput(name, Some((amount, unit)), &mut f)
    }

    fn bench_with_throughput(
        &mut self,
        name: &str,
        thr: Option<(f64, &'static str)>,
        f: &mut dyn FnMut(),
    ) -> f64 {
        // Warmup + calibration: figure out per-iter cost.
        let wstart = Instant::now();
        let mut calib_iters = 0u64;
        while wstart.elapsed() < self.warmup || calib_iters == 0 {
            f();
            calib_iters += 1;
            if calib_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = wstart.elapsed().as_secs_f64() / calib_iters as f64;

        // Choose up to ~20 samples covering the budget; expensive cases
        // (per-iteration cost beyond the budget) degrade gracefully to
        // `min_iters` single-iteration samples instead of 20× overruns.
        let budget_s = self.budget.as_secs_f64();
        let samples = ((budget_s / per_iter).ceil() as u64).clamp(self.min_iters, 20);
        let iters_per_sample =
            ((budget_s / samples as f64 / per_iter).ceil() as u64).clamp(1, self.max_iters);
        let total_target = (samples * iters_per_sample).max(self.min_iters);

        let mut times = Vec::with_capacity(samples as usize);
        let mut done = 0u64;
        while done < total_target {
            let batch = iters_per_sample.min(total_target - done);
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            times.push(t.elapsed().as_secs_f64() / batch as f64);
            done += batch;
        }
        let summary = Summary::of(&times);
        let median = summary.p50;
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: done,
            summary,
            throughput_label: thr,
        });
        median
    }

    /// Machine-readable report: group name, host parallelism, the
    /// `CCESA_THREADS` default the run used, the dispatched kernel backend
    /// (`kernels::selected` — so a report always names the GF/mask
    /// implementation it measured), and every case's statistics. This is
    /// what populates the repo's bench trajectory (`BENCH_aggregate.json`
    /// & friends).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("group", Json::str(&self.group)),
            (
                "host_cores",
                Json::Num(
                    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) as f64,
                ),
            ),
            ("default_threads", Json::Num(crate::par::threads() as f64)),
            ("kernel_backend", Json::str(crate::kernels::selected().name())),
            ("results", Json::arr(self.results.iter().map(|r| r.to_json()))),
        ])
    }

    /// Write the JSON report to `path` (pretty enough for diffing: one
    /// trailing newline, deterministic key order via `util::json`).
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
    }

    /// Standard bench-binary epilogue: write the JSON report to whatever
    /// sink [`json_sink`] resolves (`--json PATH` / `CCESA_BENCH_JSON` /
    /// `default_path`), logging the outcome. Every bench target calls this
    /// with its canonical `BENCH_<name>.json` path so its report joins the
    /// CI bench-trajectory gate (`tools/bench_gate.py`).
    pub fn write_report_to_sink(&self, default_path: &str) {
        if let Some(path) = json_sink(Some(default_path)) {
            match self.write_json(&path) {
                Ok(()) => println!("wrote {path}"),
                Err(e) => eprintln!("failed to write {path}: {e}"),
            }
        }
    }

    /// Print a formatted report for the group.
    pub fn report(&self) {
        println!("\n== bench group: {} ==", self.group);
        let width = self.results.iter().map(|r| r.name.len()).max().unwrap_or(8).max(8);
        for r in &self.results {
            let med = r.summary.p50;
            let thr = r
                .throughput_label
                .map(|(amt, unit)| {
                    let rate = amt / med;
                    if unit.starts_with("B/") {
                        format!("  {:>9.1} MiB/s", rate / (1024.0 * 1024.0))
                    } else {
                        format!("  {rate:>12.0} {unit}")
                    }
                })
                .unwrap_or_default();
            println!(
                "  {:<width$}  med {:>11}  p95 {:>11}  (n={}){thr}",
                r.name,
                fmt_time(med),
                fmt_time(r.summary.p95),
                r.iters,
            );
        }
    }
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Where a bench binary should write its JSON report, if anywhere:
/// `--json PATH` / `--json=PATH` in the binary's args (after `cargo bench
/// -- …`) wins, then the `CCESA_BENCH_JSON` env var, then `default`
/// (benches with a canonical artifact, e.g. `BENCH_aggregate.json`, pass
/// one; ad-hoc benches pass `None` and stay stdout-only).
///
/// The override names ONE file, but a bare `cargo bench` runs every
/// target — each would clobber the previous report. Use `--json` /
/// `CCESA_BENCH_JSON` only with a single `--bench <target>`; multi-target
/// sweeps (CI) should rely on the per-target defaults.
pub fn json_sink(default: Option<&str>) -> Option<String> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            if let Some(p) = args.next() {
                return Some(p);
            }
        } else if let Some(p) = a.strip_prefix("--json=") {
            return Some(p.to_string());
        }
    }
    if let Ok(p) = std::env::var("CCESA_BENCH_JSON") {
        if !p.is_empty() {
            return Some(p);
        }
    }
    default.map(str::to_string)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("CCESA_BENCH_FAST", "1");
        let mut b = Bench::new("test");
        let mut acc = 0u64;
        let med = b.bench("add", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(med > 0.0);
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].iters >= 5);
    }

    #[test]
    fn ordering_reflects_work() {
        std::env::set_var("CCESA_BENCH_FAST", "1");
        let mut b = Bench::new("order");
        let cheap = b.bench("cheap", || {
            black_box(1u64 + 1);
        });
        let pricey = b.bench("pricey", || {
            let mut s = 0u64;
            for i in 0..2000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(pricey > cheap, "pricey={pricey} cheap={cheap}");
    }

    #[test]
    fn json_report_round_trips() {
        std::env::set_var("CCESA_BENCH_FAST", "1");
        let mut b = Bench::new("jsontest");
        b.throughput("case", 1024.0, "B/s", || {
            black_box(2u64 + 2);
        });
        let j = b.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("group").as_str(), Some("jsontest"));
        assert!(parsed.get("host_cores").as_u64().unwrap() >= 1);
        assert!(parsed.get("default_threads").as_u64().unwrap() >= 1);
        let backend = parsed.get("kernel_backend").as_str().unwrap();
        assert!(["scalar", "table", "clmul"].contains(&backend), "{backend}");
        let results = parsed.get("results").as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("name").as_str(), Some("case"));
        assert!(results[0].get("median_s").as_f64().unwrap() > 0.0);
        assert!(results[0].get("p95_s").as_f64().unwrap() > 0.0);
        assert!(results[0].get("throughput").as_f64().unwrap() > 0.0);
        assert_eq!(results[0].get("throughput_unit").as_str(), Some("B/s"));
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
