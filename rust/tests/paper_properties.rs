//! Property-style integration tests pinning the paper's theorems against
//! the full implementation (crypto included), plus cross-layer invariants
//! that unit tests cannot see.

use ccesa::analysis::bounds::{p_star, per_step_q, t_rule};
use ccesa::analysis::montecarlo::estimate_failure_rates;
use ccesa::gf::gf65536 as gf;
use ccesa::protocol::adversary::{attack, theorem2_private, unmasking_attack_feasible};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::shamir::{self, Share};
use ccesa::util::rng::Rng;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

/// Theorem 1 ⟺ implementation, with the full crypto stack, across a
/// randomized sweep of topologies / thresholds / dropout regimes.
#[test]
fn theorem1_iff_reliability_full_stack_sweep() {
    let mut checked = 0;
    for seed in 0..30u64 {
        let mut meta = Rng::new(7000 + seed);
        let n = 8 + meta.gen_range(10) as usize;
        let p = 0.35 + 0.6 * meta.next_f64();
        let t = 2 + meta.gen_range(5) as usize;
        let q = 0.12 * meta.next_f64();
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Iid { q },
            ..base(n, t, 6, Topology::ErdosRenyi { p }, seed)
        };
        let m = models(n, 6, seed);
        if let Ok(r) = run_round(&cfg, &m) {
            assert_eq!(r.reliable, r.theorem1_holds, "seed={seed} sets={:?}", r.sets);
            if r.reliable {
                assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3, "seed={seed}");
            }
            checked += 1;
        }
    }
    assert!(checked >= 15, "too many aborted rounds ({checked} checked)");
}

/// Theorem 2 ⟺ the constructive eavesdropper attack, full stack.
#[test]
fn theorem2_iff_attack_full_stack_sweep() {
    let mut outcomes = [0usize; 2];
    for seed in 0..40u64 {
        let mut meta = Rng::new(9000 + seed);
        let n = 10 + meta.gen_range(8) as usize;
        let p = 0.15 + 0.25 * meta.next_f64();
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Iid { q: 0.05 },
            ..base(n, 2, 4, Topology::ErdosRenyi { p }, 50 + seed)
        };
        let m = models(n, 4, seed);
        let Ok(r) = run_round(&cfg, &m) else { continue };
        let breaches = attack(&r.transcript);
        let private = theorem2_private(&r.transcript, &r.sets.v4);
        assert_eq!(breaches.is_empty(), private, "seed={seed}");
        outcomes[usize::from(private)] += 1;
        for b in &breaches {
            let mut expect = vec![0u64; 4];
            for &i in &b.subset {
                for (a, x) in expect.iter_mut().zip(&m[i]) {
                    *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
                }
            }
            assert_eq!(b.partial_sum, expect, "seed={seed}: wrong recovered sum");
        }
    }
    assert!(outcomes[0] > 0, "converse never exercised");
    assert!(outcomes[1] > 0, "forward direction never exercised");
}

/// At p = p*(n, q_total) with Remark-4 t, rounds are a.s. reliable and
/// private — the paper's headline operating point, on the full stack.
#[test]
fn operating_point_p_star_is_reliable_and_private() {
    let n = 60;
    let q_total = 0.05;
    let p = p_star(n, q_total); // well above threshold for n=60
    let t = t_rule(n, p).min(n / 2);
    let q = per_step_q(q_total);
    let mut reliable = 0;
    let mut private = 0;
    let trials = 12;
    for seed in 0..trials {
        let cfg = ProtocolConfig {
            dropout: DropoutModel::Iid { q },
            ..base(n, t, 8, Topology::ErdosRenyi { p }, 300 + seed)
        };
        let m = models(n, 8, seed);
        let Ok(r) = run_round(&cfg, &m) else { continue };
        if r.reliable {
            reliable += 1;
        }
        if attack(&r.transcript).is_empty() {
            private += 1;
        }
    }
    assert!(reliable >= trials - 1, "reliable {reliable}/{trials}");
    assert_eq!(private, trials, "privacy breached at p*");
}

/// Remark 4's t defeats the unmasking attack: with t from the rule, no
/// node has 2t live closed-neighbors.
#[test]
fn remark4_t_blocks_unmasking_attack() {
    for n in [40usize, 100, 200] {
        let p = p_star(n, 0.0);
        let t = t_rule(n, p);
        let mut feasible = 0usize;
        let mut total = 0usize;
        for seed in 0..10u64 {
            let mut rng = Rng::new(4000 + seed);
            let g = ccesa::graph::Graph::erdos_renyi(n, p, &mut rng);
            let v4: Vec<usize> = (0..n).collect(); // worst case: nobody drops
            for i in 0..n {
                total += 1;
                if unmasking_attack_feasible(&g, &v4, t, i) {
                    feasible += 1;
                }
            }
        }
        // Prop. 1: asymptotically almost surely zero; allow a whisker
        assert!(
            (feasible as f64) < 0.01 * total as f64,
            "n={n}: unmasking feasible for {feasible}/{total}"
        );
    }
}

/// Monte-Carlo failure rates at the Fig 4.1 operating points stay within
/// the plotted bounds (reliability ≤ ~1e-2, privacy ≈ 0).
#[test]
fn fig41_operating_points_empirically_safe() {
    for (n, q_total) in [(100usize, 0.0f64), (100, 0.1), (200, 0.05)] {
        let p = p_star(n, q_total);
        let q = per_step_q(q_total);
        let t = t_rule(n, p);
        let est = estimate_failure_rates(n, p, q, t, 300, 42);
        assert!(
            est.p_e_reliability <= 0.05,
            "n={n} q={q_total}: rel fail {}",
            est.p_e_reliability
        );
        assert!(
            est.p_e_privacy <= 0.01,
            "n={n} q={q_total}: priv fail {}",
            est.p_e_privacy
        );
    }
}

/// Shamir over GF(2^16), property 1: across randomized (K, t, n) sweeps,
/// *every* t-subset sampled reconstructs the exact secret — not just the
/// first t shares the unit tests use.
#[test]
fn shamir_any_t_subset_reconstructs_randomized_sweep() {
    let mut rng = Rng::new(0x5AA1);
    for trial in 0..40u64 {
        let n = 3 + rng.gen_range(40) as usize; // holders
        let t = 2 + rng.gen_range((n - 1) as u64) as usize; // threshold 2..=n
        let klen = 1 + rng.gen_range(48) as usize; // secret bytes
        let mut secret = vec![0u8; klen];
        rng.fill_bytes(&mut secret);
        // non-contiguous evaluation points exercise arbitrary client ids
        let points: Vec<u16> = (0..n).map(|i| (3 * i + 1) as u16).collect();
        let shares = shamir::split(&secret, t, &points, &mut rng).unwrap();
        for _ in 0..4 {
            let idx = rng.sample_indices(n, t);
            let picked: Vec<Share> = idx.iter().map(|&i| shares[i].clone()).collect();
            assert_eq!(
                shamir::reconstruct(&picked, t, klen).unwrap(),
                secret,
                "trial={trial} n={n} t={t} K={klen} subset={idx:?}"
            );
        }
    }
}

/// Shamir over GF(2^16), property 2: any (t−1)-subset is consistent with
/// EVERY candidate secret. Reconstruction is linear in a forged t-th share,
/// so for each candidate chunk value we can solve for the forged evaluation
/// that makes reconstruction yield exactly that candidate — if the solution
/// always exists and verifies, the t−1 real shares pin down nothing.
#[test]
fn shamir_t_minus_one_consistent_with_every_secret() {
    let mut rng = Rng::new(0x5AA2);
    for trial in 0..25u64 {
        let n = 3 + rng.gen_range(12) as usize;
        let t = 2 + rng.gen_range((n - 1) as u64) as usize;
        let mut secret = [0u8; 2]; // one GF(2^16) chunk
        rng.fill_bytes(&mut secret);
        let points: Vec<u16> = (1..=n as u16).collect();
        let shares = shamir::split(&secret, t, &points, &mut rng).unwrap();
        let idx = rng.sample_indices(n, t - 1);
        let known: Vec<Share> = idx.iter().map(|&i| shares[i].clone()).collect();
        let forged_x = (n + 7) as u16; // fresh evaluation point

        // reconstruction(y) = base ⊕ coeff·y: probe y = 0 and y = 1
        let rec = |y: u16| -> u16 {
            let mut picked = known.clone();
            picked.push(Share { x: forged_x, y: vec![y] });
            let b = shamir::reconstruct(&picked, t, 2).unwrap();
            u16::from_le_bytes([b[0], b[1]])
        };
        let base = rec(0);
        let coeff = gf::add(rec(1), base);
        assert_ne!(coeff, 0, "trial={trial}: forged share must influence the result");

        for candidate in [0u16, 1, 0x1234, 0xFFFF, u16::from_le_bytes(secret)] {
            let y = gf::div(gf::add(candidate, base), coeff);
            assert_eq!(
                rec(y),
                candidate,
                "trial={trial} n={n} t={t}: candidate {candidate:#06x} inconsistent \
                 with {} real shares",
                t - 1
            );
        }
    }
}

/// Shamir + engine: the t-threshold is sharp on the full stack. At
/// |V4| = t the round recovers; at |V4| = t−1 it is detected unreliable —
/// across randomized (n, t).
#[test]
fn shamir_threshold_sharpness_through_engine() {
    let mut meta = Rng::new(0x5AA3);
    for trial in 0..8u64 {
        let n = 6 + meta.gen_range(8) as usize;
        let t = 3 + meta.gen_range(3) as usize;
        if t >= n {
            continue;
        }
        for &(keep, expect_reliable) in &[(t, true), (t - 1, false)] {
            let drop_at_3: Vec<usize> = (keep..n).collect();
            let cfg = ProtocolConfig {
                dropout: DropoutModel::Targeted {
                    per_step: [vec![], vec![], vec![], drop_at_3],
                },
                ..base(n, t, 4, Topology::Complete, 9100 + trial)
            };
            let m = models(n, 4, trial);
            let r = run_round(&cfg, &m).unwrap();
            assert_eq!(r.sets.v4.len(), keep, "trial={trial}");
            assert_eq!(r.reliable, expect_reliable, "trial={trial} n={n} t={t} keep={keep}");
            if expect_reliable {
                assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
            } else {
                assert!(r.sum.is_none());
            }
        }
    }
}

/// SA is CCESA with the complete graph: byte accounting must coincide with
/// an explicit K_n custom topology.
#[test]
fn sa_equals_ccesa_on_complete_graph() {
    let n = 12;
    let dim = 20;
    let m = models(n, dim, 77);
    let a = run_round(&base(n, 5, dim, Topology::Complete, 9), &m).unwrap();
    let g = ccesa::graph::Graph::complete(n);
    let b = run_round(&base(n, 5, dim, Topology::Custom(g), 9), &m).unwrap();
    assert_eq!(a.sum, b.sum);
    assert_eq!(a.stats.server_total(), b.stats.server_total());
}
