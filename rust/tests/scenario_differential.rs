//! The scenario differential harness at full width: ≥200 randomized
//! scenarios (mixed topology schedules, churn models, adversary sets and
//! payload codecs) must run bit-identically through every executor — sync
//! engine and worker-pool event loop — plus a dedicated ≥100-scenario
//! sparse-codec sweep, and a 5-round campaign at n = 1000 clients must
//! complete with all executors in exact agreement.

use ccesa::protocol::Topology;
use ccesa::sim::{
    random_scenario, run_campaign, run_differential, run_differential_batch, AdversarySpec,
    ChurnModel, CodecSpec, DiffSpec, Executor, Scenario, ThresholdRule, TopologySchedule,
};

/// The acceptance sweep: 200 seeded random scenarios, zero mismatches
/// across both non-reference executors. Failures arrive pre-shrunk with a
/// quotable seed and the name of the shape that diverged.
#[test]
fn differential_200_randomized_scenarios() {
    let report = run_differential_batch(0xD1FF_0000, 200);
    assert_eq!(report.scenarios_run, 200);
    assert!(report.rounds_run >= 200, "every scenario has at least one round");
    assert!(
        report.ok(),
        "{} mismatches; first (shrunk): {:?}",
        report.failures.len(),
        report.failures.first()
    );
}

/// Sparse payload codecs through the full differential: ≥100 randomized
/// scenarios forced onto TopK/RandK — the engine and the event loop must
/// stay bit-identical when the masked payload is a packed k-window vector,
/// across every churn model, topology schedule and dropout pattern the
/// generator produces.
#[test]
fn sparse_codec_differential_100_scenarios() {
    // the acceptance criterion asks for ≥100 sparse scenarios; 120 forced-
    // sparse seeds clear it with margin
    let failures = sparse_codec_sweep(0x5AC0_DEC0, 120);
    assert!(
        failures.is_empty(),
        "{} sparse mismatches; first: {:?}",
        failures.len(),
        failures.first()
    );
}

/// Forced-sparse differential sweep: every scenario gets a TopK/RandK
/// codec (alternating) before diffing engine vs event loop.
fn sparse_codec_sweep(base_seed: u64, count: u64) -> Vec<ccesa::sim::Mismatch> {
    let mut failures = Vec::new();
    for i in 0..count {
        let mut sc = random_scenario(base_seed + i);
        sc.codec = if i % 2 == 0 {
            CodecSpec::TopK { frac: 0.3 }
        } else {
            CodecSpec::RandK { frac: 0.3 }
        };
        sc.name = format!("sparse-{}-{i}", sc.codec.name());
        if let Some(m) = run_differential(&DiffSpec::Flat(&sc)) {
            failures.push(m);
        }
    }
    failures
}

/// Extended sparse sweep for the dedicated CI sparse-codec job
/// (`--ignored`): 300 scenarios from a disjoint seed range, beyond the
/// tier-1 budget.
#[test]
#[ignore = "extended sparse sweep (~minutes): run explicitly — CI sparse-codec job"]
fn sparse_codec_differential_extended_300() {
    let failures = sparse_codec_sweep(0xE07_5AC0, 300);
    assert!(
        failures.is_empty(),
        "{} sparse mismatches; first: {:?}",
        failures.len(),
        failures.first()
    );
}

/// The generator actually exercises the space the harness claims to cover.
#[test]
fn generator_covers_topologies_churn_and_adversaries() {
    let mut churn_kinds = std::collections::BTreeSet::new();
    let mut topo_kinds = std::collections::BTreeSet::new();
    let mut codec_kinds = std::collections::BTreeSet::new();
    let mut colluding = 0usize;
    let mut multi_round = 0usize;
    for seed in 0..200u64 {
        let sc = random_scenario(0xD1FF_0000 + seed);
        codec_kinds.insert(sc.codec.name());
        churn_kinds.insert(match sc.churn {
            ChurnModel::None => "none",
            ChurnModel::Iid { .. } => "iid",
            ChurnModel::Bursty { .. } => "bursty",
            ChurnModel::CorrelatedRegional { .. } => "regional",
            ChurnModel::TargetedAdaptive { .. } => "adaptive",
            ChurnModel::Scripted { .. } => "scripted",
        });
        topo_kinds.insert(match sc.topology {
            TopologySchedule::Static(Topology::Complete) => "complete",
            TopologySchedule::Static(Topology::ErdosRenyi { .. }) => "er",
            TopologySchedule::Static(Topology::Harary { .. }) => "harary",
            TopologySchedule::Static(Topology::Custom(_)) => "custom",
            TopologySchedule::Rotating(_) => "rotating",
            TopologySchedule::ErRamp { .. } => "ramp",
        });
        if matches!(sc.adversary, AdversarySpec::Colluding(_)) {
            colluding += 1;
        }
        if sc.rounds > 1 {
            multi_round += 1;
        }
    }
    assert!(churn_kinds.len() >= 5, "churn kinds: {churn_kinds:?}");
    assert!(topo_kinds.len() >= 5, "topology kinds: {topo_kinds:?}");
    assert_eq!(codec_kinds.len(), 3, "codec kinds: {codec_kinds:?}");
    assert!(colluding >= 20, "colluding adversaries: {colluding}/200");
    assert!(multi_round >= 60, "multi-round scenarios: {multi_round}/200");
}

/// Acceptance smoke: a 5-round campaign at n = 1000 clients completes under
/// every executor with bit-identical sums, survivor sets and NetStats,
/// stays reliable under scripted churn, and never disagrees with Theorem 1.
#[test]
fn campaign_smoke_n1000_five_rounds_bit_identical() {
    let n = 1000;
    let sc = Scenario {
        name: "smoke-n1000".to_string(),
        n,
        dim: 8,
        mask_bits: 32,
        rounds: 5,
        // fixed degree 8 keeps the n=1000 round tractable and provably
        // reliable: every client retains ≥ 9−3 closed-neighborhood share
        // holders, well above t = 4
        topology: TopologySchedule::Static(Topology::Harary { k: 8 }),
        churn: ChurnModel::Scripted {
            rounds: vec![
                [vec![], vec![17], vec![403], vec![]],
                [vec![999], vec![], vec![], vec![500, 501]],
                [vec![], vec![], vec![], vec![]],
                [vec![], vec![], vec![250, 251], vec![]],
                [vec![3], vec![], vec![], vec![998]],
            ],
        },
        adversary: AdversarySpec::Eavesdropper,
        threshold: ThresholdRule::Fixed(4),
        codec: CodecSpec::Dense,
        clip: 4.0,
        seed: 0x51107E,
    };

    let engine = run_campaign(&sc, Executor::Engine).unwrap();
    assert_eq!(engine.rounds(), 5);
    for alt in Executor::non_reference() {
        let coord = run_campaign(&sc, alt).unwrap();
        assert_eq!(coord.rounds(), 5, "{}", alt.name());
        for (e, c) in engine.records.iter().zip(&coord.records) {
            assert_eq!(e.aborted, c.aborted, "{} round {}", alt.name(), e.round);
            assert_eq!(e.sets, c.sets, "{} round {}", alt.name(), e.round);
            assert_eq!(e.sum, c.sum, "{} round {}", alt.name(), e.round);
            assert_eq!(e.stats, c.stats, "{} round {}", alt.name(), e.round);
        }
    }
    assert_eq!(engine.reliable_rounds(), 5, "scripted churn stays under threshold");
    assert_eq!(engine.aborted_rounds(), 0);
    assert_eq!(engine.theorem1_violations(), 0);

    // per-round survivor arithmetic under the script
    assert_eq!(engine.records[0].sets.v3.len(), n - 2); // 17 and 403 gone by V3
    assert_eq!(engine.records[1].sets.v3.len(), n - 1); // 999 gone at step 0
    assert_eq!(engine.records[1].sets.v4.len(), n - 3); // plus 500, 501 at step 3
    assert_eq!(engine.records[2].sets.v3.len(), n);

    // the exact sum over V3 for every round
    for rec in &engine.records {
        let models = sc.round_models(rec.round);
        let mut expect = vec![0u64; sc.dim];
        for &i in &rec.sets.v3 {
            for (a, x) in expect.iter_mut().zip(&models[i]) {
                *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
            }
        }
        assert_eq!(rec.sum.as_ref().unwrap(), &expect, "round {}", rec.round);
    }
}

/// The shrinker contracts it advertises: passing scenarios come back
/// unchanged, and shrink output always remains runnable.
#[test]
fn shrinker_preserves_passing_scenarios() {
    let sc = random_scenario(0x5112);
    let shrunk = ccesa::sim::shrink(&sc);
    // sc passes (the 200-sweep covers this space), so shrink is identity
    assert_eq!(shrunk.n, sc.n);
    assert_eq!(shrunk.rounds, sc.rounds);
    assert!(run_differential(&DiffSpec::Flat(&shrunk)).is_none());
}
