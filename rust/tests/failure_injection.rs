//! Failure-injection suite: malformed, spoofed and byzantine inputs must
//! be rejected or safely absorbed — the protocol's error surface is part
//! of the paper's reliability story (the server must *detect* unreliable
//! rounds, never emit a wrong sum).

use ccesa::codec::{EncodedUpdate, IndexPlan};
use ccesa::graph::Graph;
use ccesa::protocol::client::Client;
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::messages::*;
use ccesa::protocol::server::Server;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::shamir::Share;
use ccesa::util::rng::Rng;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

#[test]
fn server_rejects_spoofed_share_sender() {
    let mut s = Server::new(3, 1, 32, IndexPlan::identity(2), Graph::complete(3));
    let advs = (0..3)
        .map(|id| AdvertiseKeys { id, c_pk: [id as u8; 32], s_pk: [id as u8; 32] })
        .collect();
    s.step0_route_keys(advs).unwrap();
    let spoofed = ShareUpload {
        from: 0,
        shares: vec![EncryptedShare { from: 1, to: 2, ciphertext: vec![0; 32] }],
    };
    assert!(s.step1_route_shares(vec![spoofed]).is_err());
}

#[test]
fn server_rejects_upload_from_non_v1_client() {
    let mut s = Server::new(4, 1, 32, IndexPlan::identity(2), Graph::complete(4));
    // only clients 0..3 advertise
    let advs = (0..3)
        .map(|id| AdvertiseKeys { id, c_pk: [1; 32], s_pk: [2; 32] })
        .collect();
    s.step0_route_keys(advs).unwrap();
    let ghost = ShareUpload { from: 3, shares: vec![] };
    assert!(s.step1_route_shares(vec![ghost]).is_err());
}

#[test]
fn server_rejects_wrong_dimension_masked_input() {
    let mk_update = |len: usize| EncodedUpdate {
        values: vec![0; len],
        plan: IndexPlan::identity(len),
    };
    let mut s = Server::new(3, 1, 32, IndexPlan::identity(8), Graph::complete(3));
    let advs = (0..3)
        .map(|id| AdvertiseKeys { id, c_pk: [1; 32], s_pk: [2; 32] })
        .collect();
    s.step0_route_keys(advs).unwrap();
    s.step1_route_shares((0..3).map(|id| ShareUpload { from: id, shares: vec![] }).collect())
        .unwrap();
    // wrong length
    let bad = MaskedInput { id: 0, update: mk_update(4), bits: 32 };
    assert!(s.step2_collect_masked(vec![bad]).is_err());
    // wrong bit width
    let mut s2 = Server::new(3, 1, 32, IndexPlan::identity(8), Graph::complete(3));
    let advs = (0..3)
        .map(|id| AdvertiseKeys { id, c_pk: [1; 32], s_pk: [2; 32] })
        .collect();
    s2.step0_route_keys(advs).unwrap();
    s2.step1_route_shares((0..3).map(|id| ShareUpload { from: id, shares: vec![] }).collect())
        .unwrap();
    let bad = MaskedInput { id: 0, update: mk_update(8), bits: 16 };
    assert!(s2.step2_collect_masked(vec![bad]).is_err());
}

#[test]
fn server_never_emits_wrong_sum_with_forged_step3_shares() {
    // a byzantine client submits garbage shares for a dropped owner: Shamir
    // reconstruction then yields a wrong s^SK, masks fail to cancel... but
    // the protocol guarantees detection only for *missing* shares; forged
    // shares are an integrity attack the paper handles via signatures
    // (omitted cost-wise). We verify the structural guard still refuses
    // double-kind shares and that honest-majority rounds stay exact.
    let n = 8;
    let dim = 6;
    let cfg = base(n, 3, dim, Topology::Complete, 10);
    let m = models(n, dim, 2);
    let r = run_round(&cfg, &m).unwrap();
    assert!(r.reliable);
    assert_eq!(r.sum.unwrap(), r.true_sum_v3);
}

#[test]
fn client_rejects_garbage_ciphertext_blob() {
    let mut rng = Rng::new(4);
    let mut a = Client::new(0, 1, 32, vec![1], &mut rng);
    let b = Client::new(1, 1, 32, vec![0], &mut rng);
    let bundle = KeyBundle { entries: vec![(1, b.c_keys.pk, b.s_keys.pk)] };
    let _ = a.step1_share_keys(&bundle, &mut rng).unwrap();
    // a garbage "ciphertext" that is too short to even hold a tag
    let delivery = ShareDelivery {
        to: 0,
        shares: vec![EncryptedShare { from: 1, to: 0, ciphertext: vec![1, 2, 3] }],
    };
    let plan = IndexPlan::identity(4);
    let _ = a.step2_masked_input(&delivery, &[0u64; 4], &plan).unwrap();
    assert!(a.step3_unmask(&SurvivorAnnounce { v3: vec![0, 1] }).is_err());
}

#[test]
fn malformed_share_bytes_rejected() {
    assert!(Share::from_bytes(&[]).is_err());
    assert!(Share::from_bytes(&[1]).is_err()); // odd length
    assert!(Share::from_bytes(&[0, 0]).is_err()); // x = 0
    let ok = Share::from_bytes(&[1, 0, 5, 0]).unwrap();
    assert_eq!(ok.x, 1);
    assert_eq!(ok.y, vec![5]);
}

#[test]
fn whole_cohort_dropout_aborts_cleanly() {
    // everyone drops at step 0 → server cannot reach t — must error, not
    // panic or emit a sum
    let n = 6;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [(0..n).collect(), vec![], vec![], vec![]],
        },
        ..base(n, 3, 4, Topology::Complete, 3)
    };
    let m = models(n, 4, 3);
    assert!(run_round(&cfg, &m).is_err());
}

#[test]
fn exactly_threshold_survivors_still_reliable() {
    // boundary: |V4| == t
    let n = 6;
    let t = 3;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![], vec![], vec![], vec![0, 1, 2]],
        },
        ..base(n, t, 5, Topology::Complete, 8)
    };
    let m = models(n, 5, 8);
    let r = run_round(&cfg, &m).unwrap();
    assert_eq!(r.sets.v4.len(), t);
    assert!(r.reliable);
    assert_eq!(r.sum.unwrap(), r.true_sum_v3);
}

#[test]
fn one_below_threshold_survivors_unreliable_but_detected() {
    let n = 6;
    let t = 4;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![], vec![], vec![], vec![0, 1, 2]],
        },
        ..base(n, t, 5, Topology::Complete, 8)
    };
    let m = models(n, 5, 8);
    let r = run_round(&cfg, &m).unwrap();
    assert_eq!(r.sets.v4.len(), 3); // t - 1
    assert!(!r.reliable);
    assert!(r.sum.is_none());
}

#[test]
fn isolated_node_topology_handles_gracefully() {
    // a graph with an isolated vertex: that client cannot share (t=2 needs
    // a neighbor) and must withdraw; the rest aggregate fine
    let n = 6;
    let mut g = Graph::empty(n);
    for i in 1..n {
        for j in (i + 1)..n {
            g.add_edge(i, j);
        }
    } // node 0 isolated
    let cfg = base(n, 2, 4, Topology::Custom(g), 5);
    let m = models(n, 4, 5);
    let r = run_round(&cfg, &m).unwrap();
    assert!(r.reliable);
    assert!(!r.sets.v2.contains(&0), "isolated node must withdraw");
    assert_eq!(r.sum.unwrap(), r.true_sum_v3);
}

#[test]
fn zero_dimension_round_is_degenerate_but_sound() {
    let n = 4;
    let cfg = base(n, 2, 0, Topology::Complete, 6);
    let m = vec![vec![]; n];
    let r = run_round(&cfg, &m).unwrap();
    assert!(r.reliable);
    assert_eq!(r.sum.unwrap(), Vec::<u64>::new());
}

#[test]
fn non_contiguous_survivors_exercise_eval_points() {
    // heavy asymmetric dropout: survivors {3, 4, 5, 9} with gaps — checks
    // that Shamir evaluation points (id+1) work with arbitrary id sets
    let n = 10;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![0, 6], vec![1, 7], vec![2, 8], vec![]],
        },
        ..base(n, 3, 4, Topology::Complete, 12)
    };
    let m = models(n, 4, 12);
    let r = run_round(&cfg, &m).unwrap();
    assert!(r.reliable, "sets={:?}", r.sets);
    assert_eq!(r.sum.unwrap(), r.true_sum_v3);
    assert_eq!(r.sets.v3, vec![3, 4, 5, 9]);
}
