//! Integration test: the full python-AOT → rust-PJRT path.
//!
//! Loads every artifact, compiles it on the CPU PJRT client, executes it
//! with concrete inputs, and checks numerics against Rust-side oracles.
//! This is the authoritative proof that L1/L2 (Pallas + JAX) and L3 (this
//! crate) compose. Skips (with a loud message) if `make artifacts` has not
//! been run.

use ccesa::runtime::mlp::{MlpParams, MlpRuntime};
use ccesa::runtime::softreg::{SoftregParams, SoftregRuntime};
use ccesa::runtime::{to_u32, Input, Manifest, Runtime};
use ccesa::util::rng::Rng;

fn runtime_or_skip() -> Option<Runtime> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu(&dir).expect("PJRT CPU client"))
}

fn onehot(labels: &[usize], c: usize) -> Vec<f32> {
    let mut out = vec![0.0; labels.len() * c];
    for (i, &y) in labels.iter().enumerate() {
        out[i * c + y] = 1.0;
    }
    out
}

#[test]
fn mlp_train_step_learns_through_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let mlp = MlpRuntime::load(&rt).expect("load mlp artifacts");
    let dims = mlp.dims;
    let mut rng = Rng::new(0xE2E);
    let mut params = MlpParams::init(dims, &mut rng);

    // deterministic separable batch: class mean embedded in features
    let labels: Vec<usize> = (0..dims.batch).map(|i| i % dims.c).collect();
    let mut x = vec![0.0f32; dims.batch * dims.d];
    for (i, &y) in labels.iter().enumerate() {
        for j in 0..dims.d {
            x[i * dims.d + j] =
                0.3 * rng.normal() as f32 + if j % dims.c == y { 1.0 } else { 0.0 };
        }
    }
    let y1h = onehot(&labels, dims.c);

    let mut losses = Vec::new();
    for _ in 0..8 {
        let loss = mlp.train_step(&mut params, &x, &y1h, 0.5).expect("train step");
        assert!(loss.is_finite());
        losses.push(loss);
    }
    assert!(
        losses.last().unwrap() < &(0.8 * losses[0]),
        "loss did not decrease: {losses:?}"
    );

    let labels_i32: Vec<i32> = labels.iter().map(|&l| l as i32).collect();
    let correct = mlp.eval_batch(&params, &x, &labels_i32).expect("eval");
    assert!(correct > dims.batch / 2, "correct={correct}/{}", dims.batch);
}

#[test]
fn softreg_train_predict_and_invert_through_pjrt() {
    let Some(rt) = runtime_or_skip() else { return };
    let sr = SoftregRuntime::load(&rt).expect("load softreg artifacts");
    let dims = sr.dims;
    let mut rng = Rng::new(0xFACE5);

    // class templates in [0,1]^d; training batch cycles through classes
    let templates: Vec<Vec<f32>> = (0..dims.c)
        .map(|_| (0..dims.d).map(|_| rng.next_f32()).collect())
        .collect();
    let labels: Vec<usize> = (0..dims.batch).map(|i| i % dims.c).collect();
    let mut x = vec![0.0f32; dims.batch * dims.d];
    for (i, &y) in labels.iter().enumerate() {
        for j in 0..dims.d {
            x[i * dims.d + j] =
                (templates[y][j] + 0.05 * rng.normal() as f32).clamp(0.0, 1.0);
        }
    }
    let y1h = onehot(&labels, dims.c);

    let mut params = SoftregParams::zeros(dims);
    let mut first = f32::INFINITY;
    let mut last = f32::INFINITY;
    for step in 0..60 {
        last = sr.train_step(&mut params, &x, &y1h, 1.0).expect("train");
        if step == 0 {
            first = last;
        }
    }
    assert!(last < first, "loss {first} -> {last}");

    // prediction: rows sum to 1
    let probs = sr.predict(&params, &x).expect("predict");
    for row in probs.chunks(dims.c) {
        let s: f32 = row.iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row sum {s}");
    }

    // inversion attack recovers the target template better than others
    let target = 3usize;
    let mut t1h = vec![0.0f32; dims.c];
    t1h[target] = 1.0;
    let mut img = vec![0.5f32; dims.d];
    for _ in 0..60 {
        let (next, loss) = sr.inversion_step(&params, &img, &t1h, 5.0).expect("invert");
        assert!(loss.is_finite());
        img = next;
    }
    let cos = |a: &[f32], b: &[f32]| {
        let ma = a.iter().sum::<f32>() / a.len() as f32;
        let mb = b.iter().sum::<f32>() / b.len() as f32;
        let num: f32 = a.iter().zip(b).map(|(x, y)| (x - ma) * (y - mb)).sum();
        let da: f32 = a.iter().map(|x| (x - ma) * (x - ma)).sum::<f32>().sqrt();
        let db: f32 = b.iter().map(|y| (y - mb) * (y - mb)).sum::<f32>().sqrt();
        num / (da * db + 1e-9)
    };
    let sim_target = cos(&img, &templates[target]);
    let max_other = (0..dims.c)
        .filter(|&k| k != target)
        .map(|k| cos(&img, &templates[k]))
        .fold(f32::NEG_INFINITY, f32::max);
    assert!(
        sim_target > max_other,
        "inversion failed: target sim {sim_target} <= other {max_other}"
    );
}

#[test]
fn masked_sum_artifact_matches_rust_aggregation() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("masked_sum").expect("load masked_sum");
    let (clients, m) = rt.manifest.agg_dims();
    let mut rng = Rng::new(0xA66);
    let stacked: Vec<u32> = (0..clients * m).map(|_| rng.next_u32()).collect();

    let outs = exe
        .run(&[Input::U32(stacked.clone(), vec![clients as i64, m as i64])])
        .expect("execute");
    let got = to_u32(&outs[0]).expect("u32 output");

    // Rust oracle: wrapping column sum
    let mut expect = vec![0u32; m];
    for c in 0..clients {
        for j in 0..m {
            expect[j] = expect[j].wrapping_add(stacked[c * m + j]);
        }
    }
    assert_eq!(got, expect);
}

#[test]
fn quantize_artifact_matches_rust_quantizer() {
    let Some(rt) = runtime_or_skip() else { return };
    let exe = rt.load("quantize").expect("load quantize");
    let (clients, m) = rt.manifest.agg_dims();
    // aot.py fixes clip=4.0 and scale = 2^31 / (2 * clients * 4.0)
    let q = ccesa::masking::Quantizer::for_sum_of(32, 4.0, clients);
    let mut rng = Rng::new(0x9A);
    let xs: Vec<f32> = (0..m).map(|_| rng.normal_f32(0.0, 1.5)).collect();
    let outs = exe
        .run(&[Input::F32(xs.clone(), vec![m as i64])])
        .expect("execute quantize");
    let words = to_u32(&outs[0]).expect("u32 out");
    // dequantizing the kernel's words recovers the input within one step
    // of the quantizer resolution (rounding-mode differences allowed)
    let step = 1.0 / q.scale;
    for (i, (&w, &x)) in words.iter().zip(&xs).enumerate() {
        let back = q.dequantize_one(w as u64);
        let expect = x.clamp(-4.0, 4.0) as f64;
        assert!(
            (back - expect).abs() <= step + 1e-9,
            "i={i}: x={x} back={back} step={step}"
        );
    }
}
