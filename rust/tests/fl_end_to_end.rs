//! End-to-end federated learning through all three layers: synthetic data
//! → local SGD via the Pallas/JAX AOT train step on PJRT → SA/CCESA
//! secure aggregation → global model update.
//!
//! These are scaled-down versions of the experiments the examples run in
//! full (Fig 5.2 / quickstart): small client counts and few rounds keep
//! CI time bounded while still proving the layers compose.

use ccesa::codec::Codec;
use ccesa::fl::data::{partition_iid, SyntheticCifar};
use ccesa::fl::rounds::{run_fl_mlp, Aggregation, FlConfig};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::Topology;
use ccesa::runtime::mlp::MlpRuntime;
use ccesa::runtime::{Manifest, Runtime};
use ccesa::util::rng::Rng;

fn setup() -> Option<(Runtime, MlpRuntime)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    let rt = Runtime::cpu(&dir).expect("PJRT client");
    let mlp = MlpRuntime::load(&rt).expect("mlp artifacts");
    Some((rt, mlp))
}

fn base_cfg(aggregation: Aggregation) -> FlConfig {
    FlConfig {
        n_clients: 10,
        rounds: 8,
        client_fraction: 0.8,
        local_epochs: 2,
        lr: 0.5,
        clip: 4.0,
        aggregation,
        seed: 0xF1E2D,
    }
}

#[test]
fn fedavg_plain_learns() {
    let Some((_rt, mlp)) = setup() else { return };
    let mut rng = Rng::new(1);
    let dims = mlp.dims;
    let (train, test) =
        SyntheticCifar::generate_split(600, 160, dims.d, dims.c, 0.35, &mut rng);
    let parts = partition_iid(&train, 10, &mut rng);
    let hist = run_fl_mlp(&base_cfg(Aggregation::Plain), &mlp, &train, &parts, &test).unwrap();
    let acc = hist.final_accuracy();
    assert!(acc > 0.5, "fedavg accuracy {acc}");
    assert_eq!(hist.unreliable_rounds(), 0);
}

#[test]
fn secure_sa_matches_plain_within_quantization() {
    let Some((_rt, mlp)) = setup() else { return };
    let mut rng = Rng::new(2);
    let dims = mlp.dims;
    let (train, test) =
        SyntheticCifar::generate_split(600, 160, dims.d, dims.c, 0.35, &mut rng);
    let parts = partition_iid(&train, 10, &mut rng);

    let plain = run_fl_mlp(&base_cfg(Aggregation::Plain), &mlp, &train, &parts, &test).unwrap();
    let secure = run_fl_mlp(
        &base_cfg(Aggregation::Secure {
            topology: Topology::Complete,
            t_override: None,
            mask_bits: 32,
            dropout: DropoutModel::None,
            codec: Codec::Dense,
        }),
        &mlp,
        &train,
        &parts,
        &test,
    )
    .unwrap();
    assert_eq!(secure.unreliable_rounds(), 0);
    let da = (plain.final_accuracy() - secure.final_accuracy()).abs();
    assert!(
        da < 0.08,
        "SA accuracy diverged from plain: {} vs {}",
        secure.final_accuracy(),
        plain.final_accuracy()
    );
    // secure aggregation must actually cost bandwidth
    assert!(secure.total_stats.server_total() > 0);
}

#[test]
fn ccesa_er_graph_learns_with_dropout() {
    let Some((_rt, mlp)) = setup() else { return };
    let mut rng = Rng::new(3);
    let dims = mlp.dims;
    let (train, test) =
        SyntheticCifar::generate_split(600, 160, dims.d, dims.c, 0.35, &mut rng);
    let parts = partition_iid(&train, 10, &mut rng);

    let mut cfg = base_cfg(Aggregation::Secure {
        topology: Topology::ErdosRenyi { p: 0.9 },
        t_override: Some(3),
        mask_bits: 32,
        dropout: DropoutModel::Iid { q: 0.03 },
        codec: Codec::Dense,
    });
    cfg.rounds = 6;
    let hist = run_fl_mlp(&cfg, &mlp, &train, &parts, &test).unwrap();
    let acc = hist.final_accuracy();
    // a couple of unreliable rounds are tolerable; learning must proceed
    assert!(acc > 0.45, "ccesa accuracy {acc}");
    assert!(hist.unreliable_rounds() <= 3);
}

#[test]
fn ccesa_comm_cheaper_than_sa_per_round() {
    let Some((_rt, mlp)) = setup() else { return };
    let mut rng = Rng::new(4);
    let dims = mlp.dims;
    let (train, test) =
        SyntheticCifar::generate_split(400, 96, dims.d, dims.c, 0.35, &mut rng);
    let n = 16;
    let parts = partition_iid(&train, n, &mut rng);

    let mk = |agg| {
        let mut c = base_cfg(agg);
        c.n_clients = n;
        c.rounds = 2;
        c.client_fraction = 1.0;
        c
    };
    let sa = run_fl_mlp(
        &mk(Aggregation::Secure {
            topology: Topology::Complete,
            t_override: None,
            mask_bits: 32,
            dropout: DropoutModel::None,
            codec: Codec::Dense,
        }),
        &mlp,
        &train,
        &parts,
        &test,
    )
    .unwrap();
    let cc = run_fl_mlp(
        &mk(Aggregation::Secure {
            topology: Topology::ErdosRenyi { p: 0.5 },
            t_override: Some(4),
            mask_bits: 32,
            dropout: DropoutModel::None,
            codec: Codec::Dense,
        }),
        &mlp,
        &train,
        &parts,
        &test,
    )
    .unwrap();
    // total non-model traffic (keys+shares): steps 0,1,3 — CCESA < SA
    let key_traffic = |h: &ccesa::fl::rounds::FlHistory| {
        h.total_stats.bytes_up[0]
            + h.total_stats.bytes_down[0]
            + h.total_stats.bytes_up[1]
            + h.total_stats.bytes_down[1]
            + h.total_stats.bytes_up[3]
    };
    assert!(
        key_traffic(&cc) < key_traffic(&sa),
        "ccesa {} >= sa {}",
        key_traffic(&cc),
        key_traffic(&sa)
    );
}
