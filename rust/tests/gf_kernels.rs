//! Property/equivalence suite for the runtime-dispatched kernels layer.
//!
//! Two claims are pinned here, both load-bearing for mask cancellation:
//!
//! 1. **GF(2^16) slice ops are backend-exact.** Every available backend
//!    (`scalar`, `table`, `clmul` where the cpuid feature exists) computes
//!    the same field products as the scalar log/exp-table oracle, for
//!    random slices, every length class the implementations special-case
//!    (odd tails, sub-threshold short slices) and zero/one/boundary
//!    weights. A single diverging lane would silently break Shamir
//!    reconstruction.
//! 2. **Fused multi-seed mask application equals the sequential form.**
//!    `kernels::apply_masks_fused` over 1..=9 seeds at arbitrary range
//!    offsets is bit-identical to one `apply_mask_range` pass per seed,
//!    and to manual expand-then-add through the independent
//!    `expand_masks_at` path.
//!
//! The CI `kernel-matrix` job runs this suite (plus the shamir/masking
//! unit suites) once per `CCESA_KERNEL` value, so the *dispatched* paths
//! are also exercised under every backend, not just the explicit-backend
//! sweeps below.

use ccesa::crypto::prg::{
    apply_mask_jobs_range, apply_mask_range, expand_masks_at, MaskJob, NONCE_PAIRWISE, NONCE_SELF,
};
use ccesa::gf::gf65536 as gf;
use ccesa::kernels::{self, Backend, MaskStream};
use ccesa::util::{mod_mask, rng::Rng};

fn random_u16s(len: usize, rng: &mut Rng) -> Vec<u16> {
    (0..len).map(|_| rng.next_u32() as u16).collect()
}

/// Lengths crossing every implementation boundary: empty, odd tails for
/// the 2-element clmul packing, and both sides of the table backend's
/// short-slice threshold (64).
const LENS: [usize; 13] = [0, 1, 2, 3, 15, 16, 17, 63, 64, 65, 127, 256, 1001];

/// Zero, one, and boundary weights, plus the generator and high-bit cases.
const WEIGHTS: [u16; 8] = [0, 1, 2, 3, 0x8000, 0xFFFF, 0x1001, 0x1100];

#[test]
fn slice_mul_matches_scalar_oracle_on_every_backend() {
    let mut rng = Rng::new(0x6F_61F);
    for backend in kernels::available_backends() {
        for len in LENS {
            let src = random_u16s(len, &mut rng);
            for w in WEIGHTS.into_iter().chain((0..8).map(|_| rng.next_u32() as u16)) {
                let mut got = src.clone();
                kernels::gf_mul_slice_const_with(backend, &mut got, w);
                let expect: Vec<u16> = src.iter().map(|&x| gf::mul(x, w)).collect();
                assert_eq!(got, expect, "{backend:?} mul len={len} w={w:#x}");
            }
        }
    }
}

#[test]
fn slice_fma_matches_scalar_oracle_on_every_backend() {
    let mut rng = Rng::new(0x6F_FA5);
    for backend in kernels::available_backends() {
        for len in LENS {
            let src = random_u16s(len, &mut rng);
            let acc0 = random_u16s(len, &mut rng);
            for w in WEIGHTS.into_iter().chain((0..8).map(|_| rng.next_u32() as u16)) {
                let mut got = acc0.clone();
                kernels::gf_fma_slice_with(backend, &mut got, &src, w);
                let expect: Vec<u16> =
                    acc0.iter().zip(&src).map(|(&a, &x)| a ^ gf::mul(x, w)).collect();
                assert_eq!(got, expect, "{backend:?} fma len={len} w={w:#x}");
            }
        }
    }
}

#[test]
fn dispatched_ops_agree_with_explicit_selected_backend() {
    let mut rng = Rng::new(0xD15);
    let selected = kernels::selected();
    assert!(selected.available());
    let src = random_u16s(513, &mut rng);
    let w = 0xBEEF;
    let mut via_dispatch = src.clone();
    kernels::gf_mul_slice_const(&mut via_dispatch, w);
    let mut via_explicit = src.clone();
    kernels::gf_mul_slice_const_with(selected, &mut via_explicit, w);
    assert_eq!(via_dispatch, via_explicit);

    let mut acc_a = random_u16s(513, &mut rng);
    let mut acc_b = acc_a.clone();
    kernels::gf_fma_slice(&mut acc_a, &src, w);
    kernels::gf_fma_slice_with(selected, &mut acc_b, &src, w);
    assert_eq!(acc_a, acc_b);
}

#[test]
fn backend_availability_is_coherent() {
    let av = kernels::available_backends();
    assert!(av.contains(&Backend::Scalar), "scalar oracle must always exist");
    assert!(av.contains(&Backend::Table), "portable table backend must always exist");
    assert_eq!(av.contains(&Backend::Clmul), Backend::Clmul.available());
    // whatever dispatch picked is runnable here
    assert!(kernels::selected().available());
}

/// Seed counts 1..=9 (a degree-8 client's d+1 streams) × arbitrary range
/// offsets × every mask width class: the fused kernel must equal one
/// sequential `apply_mask_range` pass per stream.
#[test]
fn fused_masks_equal_sequential_per_seed_passes() {
    let mut rng = Rng::new(0xF05E_D);
    for bits in [16u32, 32, 48, 64] {
        let modm = mod_mask(bits);
        for seeds in 1..=9usize {
            let streams: Vec<MaskStream> = (0..seeds)
                .map(|k| {
                    let mut seed = [0u8; 32];
                    rng.fill_bytes(&mut seed);
                    MaskStream {
                        seed,
                        nonce: if k % 3 == 0 { NONCE_SELF } else { NONCE_PAIRWISE },
                        negate: k % 2 == 0,
                    }
                })
                .collect();
            for (start, len) in
                [(0usize, 600usize), (1, 255), (255, 258), (256, 256), (511, 130), (777, 1)]
            {
                let base: Vec<u64> = (0..len).map(|_| rng.next_u64() & modm).collect();
                let mut fused = base.clone();
                kernels::apply_masks_fused(&mut fused, &streams, bits, start);
                let mut seq = base.clone();
                for s in &streams {
                    apply_mask_range(&mut seq, &s.seed, &s.nonce, bits, s.negate, start);
                }
                assert_eq!(fused, seq, "bits={bits} seeds={seeds} start={start} len={len}");
            }
        }
    }
}

/// The job-list form the protocol paths use (`apply_mask_jobs_range`)
/// against a fully independent oracle: each stream materialized through
/// `expand_masks_at` (which never touches the fused kernel) and added
/// manually.
#[test]
fn mask_jobs_match_manual_expansion_oracle() {
    let mut rng = Rng::new(0x0AC1E);
    for bits in [16u32, 32, 48, 64] {
        let modm = mod_mask(bits);
        for seeds in [1usize, 4, 9] {
            let jobs: Vec<MaskJob> = (0..seeds)
                .map(|k| {
                    let mut seed = [0u8; 32];
                    rng.fill_bytes(&mut seed);
                    MaskJob { seed, pairwise: k % 2 == 1, negate: k % 3 == 0 }
                })
                .collect();
            for (start, len) in [(0usize, 500usize), (7, 300), (250, 270)] {
                let base: Vec<u64> = (0..len).map(|_| rng.next_u64() & modm).collect();
                let mut got = base.clone();
                apply_mask_jobs_range(&mut got, &jobs, bits, start);

                let mut expect = base;
                for job in &jobs {
                    let mut window = vec![0u64; len];
                    expand_masks_at(&job.seed, job.nonce(), bits, start, &mut window);
                    for (a, m) in expect.iter_mut().zip(&window) {
                        *a = if job.negate { a.wrapping_sub(*m) } else { a.wrapping_add(*m) }
                            & modm;
                    }
                }
                assert_eq!(got, expect, "bits={bits} seeds={seeds} start={start} len={len}");
            }
        }
    }
}

/// Sharding a fused multi-seed application across any partition composes
/// to the unsharded fused pass — the invariant `Server::finalize` and
/// client Step 2 rely on when they run the fused kernel per worker shard.
#[test]
fn fused_masks_compose_across_shards() {
    let mut rng = Rng::new(0x5AA5);
    let bits = 32u32;
    let modm = mod_mask(bits);
    let len = 777usize;
    let streams: Vec<MaskStream> = (0..5)
        .map(|k| {
            let mut seed = [0u8; 32];
            rng.fill_bytes(&mut seed);
            MaskStream { seed, nonce: NONCE_PAIRWISE, negate: k % 2 == 1 }
        })
        .collect();
    let base: Vec<u64> = (0..len).map(|_| rng.next_u64() & modm).collect();
    let mut whole = base.clone();
    kernels::apply_masks_fused(&mut whole, &streams, bits, 0);
    for split in [1usize, 16, 255, 256, 257, 776] {
        let mut sharded = base.clone();
        let (lo, hi) = sharded.split_at_mut(split);
        kernels::apply_masks_fused(lo, &streams, bits, 0);
        kernels::apply_masks_fused(hi, &streams, bits, split);
        assert_eq!(sharded, whole, "split={split}");
    }
}
