//! Cross-round session acceptance suite.
//!
//! Pins the session layer's deployment contracts from the *outside* (the
//! public API only):
//!
//! 1. **Executor equivalence, warm** — the same session campaign (cold
//!    establish + ratcheted warm rounds, absences included) is bit-identical
//!    in sums, survivor sets and logical `NetStats` across the serial
//!    engine, the worker-pool event loop and the loopback wire.
//! 2. **Re-key under churn** — absences that starve active degrees force
//!    repair edges whose endpoints re-key, identically on every executor,
//!    and the re-key traffic is visible in the dedicated counters.
//! 3. **Mid-session crash recovery** — a journaled warm round truncated
//!    mid-round recovers to a *warm* server that regenerates the pending
//!    plans; the full journal replays to the finished round's output.
//! 4. **Steady-state amortization** (`--ignored`, CI session job) — a
//!    20-round warm campaign per codec keeps mean warm setup bytes under
//!    30% of the cold round's.

use ccesa::codec::Codec;
use ccesa::coordinator::{Executor, RoundOptions};
use ccesa::journal::{self, Journal, LogWriter};
use ccesa::net::socket;
use ccesa::protocol::messages::Down;
use ccesa::protocol::session::{round_seed, Session};
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::sim::{run_session_campaign, CodecSpec, SessionScenario};
use ccesa::util::rng::Rng;
use std::path::PathBuf;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ccesa-session-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn opts_for(executor: Executor) -> RoundOptions {
    RoundOptions::builder().executor(executor).build().unwrap()
}

/// One session campaign: establish, then `rounds` warm rounds under the
/// given per-round activity schedule. Returns per-round essentials.
#[allow(clippy::type_complexity)]
fn campaign(
    cfg: &ProtocolConfig,
    cold_models: &[Vec<u64>],
    schedule: &[Vec<bool>],
    executor: Executor,
) -> (Session, Vec<(Option<Vec<u64>>, Vec<usize>, ccesa::net::NetStats)>) {
    let (mut session, _) = Session::establish(cfg, cold_models).unwrap();
    let opts = opts_for(executor);
    let records = schedule
        .iter()
        .enumerate()
        .map(|(k, active)| {
            let m = models(cfg.n, cfg.dim, 0xBEEF + k as u64);
            let r = session
                .run_round(&m, active, &opts)
                .unwrap_or_else(|e| panic!("{}: warm round {}: {e:#}", executor.name(), k + 1));
            (r.sum, r.sets.v3.clone(), r.stats)
        })
        .collect();
    (session, records)
}

/// The same warm campaign — TopK payloads, one round with absences — must
/// be bit-identical across all three executors.
#[test]
fn warm_rounds_bit_identical_across_all_three_executors() {
    let n = 10;
    let dim = 16;
    let cfg = ProtocolConfig {
        codec: Codec::TopK { k: 4 },
        ..base(n, 4, dim, Topology::Complete, 0x5E55)
    };
    let cold = models(n, dim, 1);
    // round 2 loses two members; round 3 has them back
    let mut absent = vec![true; n];
    absent[2] = false;
    absent[7] = false;
    let schedule = vec![vec![true; n], absent, vec![true; n]];

    let (_, reference) = campaign(&cfg, &cold, &schedule, Executor::Engine);
    for executor in [Executor::EventLoop, Executor::Wire] {
        let (_, got) = campaign(&cfg, &cold, &schedule, executor);
        for (k, ((esum, esets, estats), (gsum, gsets, gstats))) in
            reference.iter().zip(&got).enumerate()
        {
            let name = executor.name();
            assert_eq!(gsum, esum, "{name}: round {} sum", k + 1);
            assert_eq!(gsets, esets, "{name}: round {} V3", k + 1);
            assert!(gstats.logical_eq(estats), "{name}: round {} logical stats", k + 1);
        }
    }
}

/// Absences on a degree-t−1 Harary graph starve active degrees, so the
/// session must add repair edges, re-key their endpoints, and stay
/// bit-identical across executors while doing it.
#[test]
fn rekey_under_churn_matches_across_executors() {
    let n = 10;
    let dim = 8;
    let cfg = base(n, 5, dim, Topology::Harary { k: 4 }, 0x2E2E);
    let cold = models(n, dim, 2);
    // every node has exactly 4 = t−1 neighbors, so two absentees force
    // repairs among the remaining 8 participants
    let mut absent = vec![true; n];
    absent[1] = false;
    absent[4] = false;
    let schedule = vec![absent, vec![true; n]];

    let (session, reference) = campaign(&cfg, &cold, &schedule, Executor::Engine);
    assert!(!session.repair_edges().is_empty(), "absences must force repair edges");
    for &(_, i, j) in session.repair_edges() {
        assert!(session.graph().has_edge(i, j));
    }
    let (r1_stats, r2_stats) = (&reference[0].2, &reference[1].2);
    assert!(
        r1_stats.rekey_up > 0 && r1_stats.rekey_down > 0,
        "repair endpoints must announce fresh keys in the repairing round"
    );
    // steady state again by round 2: no new repairs, so no fresh announcements
    assert!(r2_stats.rekey_up <= r1_stats.rekey_up);

    for executor in [Executor::EventLoop, Executor::Wire] {
        let (s2, got) = campaign(&cfg, &cold, &schedule, executor);
        assert_eq!(
            s2.repair_edges(),
            session.repair_edges(),
            "{}: repair plan diverged",
            executor.name()
        );
        for (k, ((esum, esets, estats), (gsum, gsets, gstats))) in
            reference.iter().zip(&got).enumerate()
        {
            let name = executor.name();
            assert_eq!(gsum, esum, "{name}: round {} sum", k + 1);
            assert_eq!(gsets, esets, "{name}: round {} V3", k + 1);
            assert!(gstats.logical_eq(estats), "{name}: round {} logical stats", k + 1);
            assert_eq!(gstats.rekey_up, estats.rekey_up, "{name}: round {} rekey_up", k + 1);
            assert_eq!(
                gstats.rekey_down,
                estats.rekey_down,
                "{name}: round {} rekey_down",
                k + 1
            );
        }
    }
}

/// A journaled warm round's log recovers mid-session: the full journal
/// replays to the finished round, and a torn prefix (setup + phase-0 ups
/// only) rebuilds a *warm* server that regenerates the pending
/// [`Down::WarmPlan`]s — the crash window `sim::crash` covers for cold
/// rounds, here for the session path.
#[test]
fn warm_round_journal_recovers_mid_session() {
    let n = 8;
    let dim = 6;
    let cfg = base(n, 3, dim, Topology::Complete, 0x10AD);
    let cold = models(n, dim, 3);
    let (mut session, _) = Session::establish(&cfg, &cold).unwrap();
    let dir = tmp_dir("warm-recover");
    let opts = RoundOptions::builder().journal(&dir).build().unwrap();
    let m = models(n, dim, 4);
    let live = session.run_round(&m, &vec![true; n], &opts).unwrap();
    assert!(live.reliable);

    let tag = socket::round_tag(round_seed(cfg.seed, 1));
    let path = Journal::path_for(&dir, tag);

    // the complete journal replays to the finished warm round
    let rec = journal::recover(&path).unwrap();
    assert_eq!(rec.round, tag);
    assert_eq!(rec.next_phase, 4, "full warm journal must recover a finished round");
    assert!(rec.server.warm().is_some(), "warm journal must rebuild a warm server");
    assert_eq!(rec.map_bytes, 0, "dense warm rounds carry no coordinate map");
    let out = rec.output.expect("finished round carries its output");
    assert_eq!(out.sum, live.sum);
    assert_eq!(out.sets, live.sets);

    // torn mid-round: keep only the setup record and the phase-0 batch —
    // byte-for-byte what a crash between phases 0 and 1 leaves behind
    let records = journal::read_log(&path).unwrap();
    assert!(records.len() >= 3, "warm journal has setup + 4 phase batches");
    let torn = dir.join("torn.ccl");
    let mut w = LogWriter::create(&torn).unwrap();
    for rec in &records[..2] {
        w.append(rec.rec_type, rec.round, &rec.payload).unwrap();
    }
    drop(w);
    let rec = journal::recover(&torn).unwrap();
    assert_eq!(rec.next_phase, 1, "phase 0 applied, phase 1 pending");
    assert!(rec.server.warm().is_some());
    assert_eq!(rec.downs.len(), n, "every resumer is owed its warm plan");
    for (_, down) in &rec.downs {
        assert!(matches!(down, Down::WarmPlan(_)), "phase-0 downs are warm plans, got {down:?}");
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// TopK warm journals persist the coordinate-map accounting: recovery
/// re-charges the same per-recipient map bytes the live round did.
#[test]
fn topk_warm_journal_preserves_coordinate_map_accounting() {
    let n = 6;
    let dim = 20;
    let cfg = ProtocolConfig {
        codec: Codec::TopK { k: 4 },
        ..base(n, 3, dim, Topology::Complete, 0x70CC)
    };
    let cold = models(n, dim, 5);
    let (mut session, _) = Session::establish(&cfg, &cold).unwrap();
    let dir = tmp_dir("topk-map");
    let opts = RoundOptions::builder().journal(&dir).build().unwrap();
    let live = session.run_round(&models(n, dim, 6), &vec![true; n], &opts).unwrap();
    assert!(live.reliable);
    assert!(live.stats.coord_map_bytes > 0, "TopK rounds charge the coordinate map");

    let tag = socket::round_tag(round_seed(cfg.seed, 1));
    let rec = journal::recover(&Journal::path_for(&dir, tag)).unwrap();
    assert!(rec.map_bytes > 0, "recovery must re-learn the per-recipient map charge");
    let _ = std::fs::remove_dir_all(&dir);
}

/// CI session-steady-state job (`--ignored`): a 20-round warm campaign per
/// codec must keep mean warm setup bytes under 30% of the cold round's —
/// the PR's amortization acceptance bar, at a realistic population.
#[test]
#[ignore = "session campaign (~tens of seconds): run explicitly — CI session-steady-state job"]
fn session_steady_state_campaign_20_rounds_per_codec() {
    for codec in [CodecSpec::Dense, CodecSpec::TopK { frac: 0.25 }, CodecSpec::RandK { frac: 0.25 }]
    {
        let sc = SessionScenario::steady_state(codec, 20, 0xCAFE);
        let report = run_session_campaign(&sc, Executor::EventLoop)
            .unwrap_or_else(|e| panic!("{}: {e:#}", sc.name));
        assert_eq!(report.aborted_rounds(), 0, "{}", sc.name);
        let fraction = report.setup_fraction_of_cold();
        println!("{}", report.one_line());
        assert!(
            fraction < 0.30,
            "{}: steady-state setup bytes at {:.1}% of cold (bound: 30%)",
            sc.name,
            fraction * 100.0
        );
    }
}
