//! The virtual-clock acceptance surface: the clocked differential (event
//! loop under seeded latency schedules vs the sync engine with observed
//! timeout drops merged into churn) at smoke and full width, the CI-pinned
//! straggler deadline-vs-reliability tradeoff, and the TOML round-spec
//! path driving the same sweep end to end.

use ccesa::sim::{
    run_clocked_differential, run_clocked_plan, run_timeout_sweep, straggler_scenario,
};
use ccesa::spec::RoundSpec;
use std::sync::Arc;

/// Tier-1 smoke: a slice of the clocked differential runs clean. The full
/// ≥100-scenario sweep is the `--ignored` acceptance test below.
#[test]
fn clocked_differential_smoke_12_scenarios() {
    let report = run_clocked_differential(0xC10C_D1FF, 12);
    assert_eq!(report.scenarios_run, 12);
    assert!(report.rounds_run >= 12, "every scenario has at least one round");
    assert!(
        report.ok(),
        "{} clocked mismatches; first: {:?}",
        report.failures.len(),
        report.failures.first()
    );
}

/// Acceptance criterion: ≥100 randomized clocked scenarios, zero
/// mismatches between the clocked event loop and its engine reference —
/// timeout-dropped clients behave bit-identically to churned clients.
#[test]
#[ignore = "full clocked differential (~minutes): run explicitly — CI virtual-clock job"]
fn clocked_differential_acceptance_120_scenarios() {
    let report = run_clocked_differential(0xC10C_ACC0, 120);
    assert_eq!(report.scenarios_run, 120);
    assert!(
        report.ok(),
        "{} clocked mismatches; first: {:?}",
        report.failures.len(),
        report.failures.first()
    );
}

/// The CI-pinned tradeoff scenario: half the cohort straggles at 20–40 ms
/// against a threshold above the fast-cohort size. A 5 ms deadline drops
/// the slow half, |V1| < t and rounds abort (the Theorem-1 reliability
/// failure); a 100 ms deadline keeps everyone and all rounds succeed —
/// at the price of simulated latency.
#[test]
fn timeout_sweep_straggler_tradeoff() {
    let (sc, clock) = straggler_scenario(0x51EE9);
    let report = run_timeout_sweep(&sc, &clock, &[5_000, 100_000], 0);
    assert_eq!(report.points.len(), 2);
    let short = &report.points[0];
    let long = &report.points[1];

    // short deadline: stragglers dropped, reliability lost
    assert!(short.timeout_drops > 0, "5 ms must drop the 20–40 ms tail: {short:?}");
    assert!(short.aborted_rounds > 0, "|V1| < t must abort: {short:?}");
    assert!(short.reliable_rounds < long.reliable_rounds, "{short:?} vs {long:?}");

    // long deadline: everyone delivers, every round reliable — no privacy
    // regression either way (the eavesdropper never breaches)
    assert_eq!(long.reliable_rounds, 3, "past the tail every round succeeds");
    assert_eq!(long.aborted_rounds, 0);
    assert_eq!(long.timeout_drops, 0);
    assert_eq!(long.breached_rounds, 0);
    assert_eq!(long.theorem1_violations, 0);

    // the cost axis: waiting out stragglers is slower in virtual time
    assert!(
        short.mean_round_latency_us < long.mean_round_latency_us,
        "latency must grow with the deadline: {} vs {}",
        short.mean_round_latency_us,
        long.mean_round_latency_us
    );

    let rendered = report.render();
    assert!(rendered.contains("straggler-tradeoff"));
    assert!(rendered.contains("deadline_us"));
}

/// The TOML spec path end to end: a `[timeouts]` + `[clock]` spec compiles
/// to the same scenario/policy/schedule the library API builds by hand,
/// and a single clocked round driven off the spec replays bit-identically.
#[test]
fn spec_file_drives_clocked_rounds_deterministically() {
    let text = r#"
        [round]
        n = 10
        dim = 6
        seed = 0xC10C_5BEC
        t = 4
        rounds = 2

        [timeouts]
        uniform_ms = 8
        min_survivors = 5

        [clock]
        link = "uniform"
        lo_us = 50
        hi_us = 2000
        compute_lo_us = 10
        compute_hi_us = 100
    "#;
    let spec = RoundSpec::from_toml_str(text).unwrap();
    let csc = spec.clocked_scenario("spec-clocked").expect("[clock] section compiles");
    assert_eq!(csc.base.n, 10);
    assert_eq!(csc.policy, spec.timeout_policy().unwrap());
    assert_eq!(csc.policy.min_survivors, 5);

    let plans = csc.base.compile();
    assert_eq!(plans.len(), 2);
    for plan in &plans {
        let models = csc.base.round_models(plan.round);
        let sched = Arc::new(csc.schedule_for(plan.round));
        let a = run_clocked_plan(plan, &models, &sched, &csc.policy, &[]);
        let b = run_clocked_plan(plan, &models, &sched, &csc.policy, &[]);
        assert_eq!(a.timeline, b.timeline, "round {}: same spec ⇒ same timeline", plan.round);
        assert_eq!(a.clocked, b.clocked, "round {}: same spec ⇒ same record", plan.round);
        // the engine reference agrees whenever the clocked run finished
        if !a.clocked.aborted {
            assert_eq!(a.engine.sets, a.clocked.sets, "round {}", plan.round);
            assert_eq!(a.engine.sum, a.clocked.sum, "round {}", plan.round);
        }
    }
}

/// A spec with `sweep_ms` carries the whole sweep axis: the deadlines the
/// CLI would run are exactly the ones the report scores, in order.
#[test]
fn spec_sweep_axis_matches_report_points() {
    let text = r#"
        [round]
        n = 8
        dim = 4
        seed = 7
        t = 3
        rounds = 1

        [timeouts]
        uniform_ms = 5
        sweep_ms = [2, 50]

        [clock]
        link = "uniform"
        lo_us = 100
        hi_us = 1500
    "#;
    let spec = RoundSpec::from_toml_str(text).unwrap();
    let ts = spec.timeouts.as_ref().unwrap();
    assert_eq!(ts.sweep_ms, vec![2, 50]);
    let sc = spec.scenario("spec-sweep");
    let clock = spec.clock.as_ref().unwrap();
    let deadlines: Vec<u64> = ts.sweep_ms.iter().map(|ms| ms * 1_000).collect();
    let report = run_timeout_sweep(&sc, clock, &deadlines, ts.min_survivors);
    assert_eq!(report.points.len(), 2);
    assert_eq!(report.points[0].deadline_us, 2_000);
    assert_eq!(report.points[1].deadline_us, 50_000);
    assert_eq!(report.min_survivors, ts.min_survivors);
}
