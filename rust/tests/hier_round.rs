//! Hierarchical (two-level sharded) rounds end to end: builder floor and
//! shard-boundary edge cases, the single-shard flat degeneracy, engine ↔
//! event-loop parity, clean degradation when a shard aggregator is lost,
//! the randomized hier differential with the flat engine as sum oracle
//! (tier-1 smoke + `--ignored` ≥100-scenario acceptance sweep for the CI
//! hierarchical job), and an `--ignored` n = 10⁵ scale smoke.

use ccesa::coordinator::Executor;
use ccesa::hier::{HierOptions, HierRunner, ShardPlan};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::sim::{run_hier_campaign, run_hier_differential, storm_scenarios};
use ccesa::util::rng::Rng;

fn models_for(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect()).collect()
}

fn hier_topology(shards: usize, intra: Topology, root: Topology) -> Topology {
    Topology::Hierarchical { shards, intra: Box::new(intra), root: Box::new(root) }
}

fn runner(executor: Executor) -> HierRunner {
    HierRunner::new(HierOptions {
        executor,
        check_theorem1: true,
        check_truth: true,
        ..HierOptions::default()
    })
}

/// The builder floor: a shard that cannot lose even one client (smallest
/// shard ≤ t) is rejected at build time, not discovered as an abort.
#[test]
fn builder_rejects_shards_below_threshold_plus_one() {
    let build = |n: usize, t: usize, shards: usize| {
        ProtocolConfig::builder()
            .clients(n)
            .threshold(t)
            .model_dim(4)
            .topology(hier_topology(shards, Topology::Complete, Topology::Complete))
            .seed(1)
            .build()
    };
    // n=12 in 4 shards → smallest shard 3 < t+1 = 4
    let err = build(12, 3, 4).unwrap_err().to_string();
    assert!(err.contains("t+1"), "unexpected error: {err}");
    // the same population in 3 shards of 4 clears the floor
    assert!(build(12, 3, 3).is_ok());
    // more shards than clients
    assert!(build(6, 1, 7).is_err());
    // zero shards
    assert!(build(6, 1, 0).is_err());
}

/// Remainder populations: when `n % shards != 0` the first shards take one
/// extra client, every client lands in exactly one shard, and the round
/// still sums exactly.
#[test]
fn remainder_shards_cover_every_client_and_sum_exactly() {
    let plan = ShardPlan::new(13, 3).unwrap();
    assert_eq!(
        (0..3).map(|s| plan.range(s)).collect::<Vec<_>>(),
        vec![(0, 5), (5, 9), (9, 13)],
    );
    for c in 0..13 {
        let s = plan.shard_of(c);
        let (lo, hi) = plan.range(s);
        assert!(lo <= c && c < hi, "client {c} not inside its shard {s}");
    }

    let cfg = ProtocolConfig::builder()
        .clients(13)
        .threshold(3)
        .model_dim(6)
        .topology(hier_topology(3, Topology::Complete, Topology::Complete))
        .seed(0xBEEF)
        .build()
        .unwrap();
    let models = models_for(13, 6, 2);
    let r = runner(Executor::Engine).run(&cfg, &models).unwrap();
    assert!(r.reliable);
    assert_eq!(r.global_v3, (0..13).collect::<Vec<_>>());
    assert_eq!(r.sum, r.true_sum);
    assert_eq!(r.shard_reports.len(), 3);
    assert!(r.shard_reports.iter().all(|s| s.completed && s.reliable));
}

/// `--shards 1` is the flat protocol: same sum, same survivor sets, same
/// logical traffic as `protocol::engine::run_round` on the intra topology.
#[test]
fn single_shard_round_is_bit_identical_to_flat() {
    let n = 9;
    let dim = 5;
    let drops = DropoutModel::Targeted {
        per_step: [vec![2], vec![], vec![7], vec![]],
    };
    let flat_cfg = ProtocolConfig::builder()
        .clients(n)
        .threshold(3)
        .model_dim(dim)
        .topology(Topology::ErdosRenyi { p: 0.9 })
        .dropout(drops.clone())
        .seed(0x51C)
        .build()
        .unwrap();
    let hier_cfg = ProtocolConfig::builder()
        .clients(n)
        .threshold(3)
        .model_dim(dim)
        .topology(hier_topology(1, Topology::ErdosRenyi { p: 0.9 }, Topology::Complete))
        .dropout(drops)
        .seed(0x51C)
        .build()
        .unwrap();
    let models = models_for(n, dim, 3);
    let flat = run_round(&flat_cfg, &models).unwrap();
    let hier = runner(Executor::Engine).run(&hier_cfg, &models).unwrap();
    assert_eq!(hier.sum, flat.sum);
    assert_eq!(hier.global_v3, flat.sets.v3);
    assert_eq!(hier.shard_reports.len(), 1);
    assert_eq!(hier.shard_reports[0].sets, flat.sets);
    assert!(hier.root.is_none(), "single shard runs no root round");
    assert!(hier.stats.intra.logical_eq(&flat.stats));
    assert_eq!(hier.stats.root.server_total(), 0);
}

/// Engine and event loop must agree bit-for-bit on a multi-shard round
/// with client churn *and* a scheduled aggregator failure.
#[test]
fn executors_agree_on_multi_shard_round_with_agg_failure() {
    let n = 16;
    let cfg = ProtocolConfig::builder()
        .clients(n)
        .threshold(2)
        .model_dim(12)
        .topology(hier_topology(4, Topology::Complete, Topology::Complete))
        .dropout(DropoutModel::Targeted {
            per_step: [vec![5], vec![], vec![11], vec![]],
        })
        .seed(0xAB)
        .build()
        .unwrap();
    let models = models_for(n, 12, 4);
    let opts = |executor| HierOptions {
        executor,
        agg_dropout: [vec![], vec![3], vec![], vec![]],
        check_theorem1: true,
        check_truth: true,
        ..HierOptions::default()
    };
    let e = HierRunner::new(opts(Executor::Engine)).run(&cfg, &models).unwrap();
    let l = HierRunner::new(opts(Executor::EventLoop)).run(&cfg, &models).unwrap();
    assert_eq!(e.sum, l.sum);
    assert_eq!(e.global_v3, l.global_v3);
    assert_eq!(e.reliable, l.reliable);
    for (s, (a, b)) in e.shard_reports.iter().zip(&l.shard_reports).enumerate() {
        assert_eq!(a.sets, b.sets, "shard {s}");
    }
    assert_eq!(
        e.root.as_ref().map(|r| r.sets.clone()),
        l.root.as_ref().map(|r| r.sets.clone()),
    );
    assert!(e.stats.intra.logical_eq(&l.stats.intra));
    assert!(e.stats.root.logical_eq(&l.stats.root));
}

/// Losing a shard aggregator degrades the global sum to *dropping that
/// shard* — the covered set shrinks by exactly that shard's V3, and the
/// sum still equals the plaintext truth over what remains.
#[test]
fn lost_aggregator_degrades_to_dropping_its_shard() {
    let n = 15;
    let cfg = ProtocolConfig::builder()
        .clients(n)
        .threshold(3)
        .model_dim(8)
        .topology(hier_topology(3, Topology::Complete, Topology::Complete))
        .seed(0xD0A)
        .build()
        .unwrap();
    let models = models_for(n, 8, 5);
    let run = |lost: &[usize]| {
        let mut agg_dropout: [Vec<usize>; 4] = Default::default();
        agg_dropout[0] = lost.to_vec();
        HierRunner::new(HierOptions {
            executor: Executor::Engine,
            agg_dropout,
            check_truth: true,
            ..HierOptions::default()
        })
        .run(&cfg, &models)
        .unwrap()
    };
    let healthy = run(&[]);
    assert_eq!(healthy.global_v3, (0..n).collect::<Vec<_>>());
    assert_eq!(healthy.sum, healthy.true_sum);

    let degraded = run(&[1]);
    assert!(degraded.reliable);
    let plan = ShardPlan::new(n, 3).unwrap();
    let (lo, hi) = plan.range(1);
    let expect: Vec<usize> = (0..n).filter(|c| *c < lo || *c >= hi).collect();
    assert_eq!(degraded.global_v3, expect, "exactly shard 1 is dropped");
    // the invariant that matters: never a corrupted sum, only a smaller one
    assert_eq!(degraded.sum, degraded.true_sum);
    assert_ne!(degraded.sum, healthy.sum);
}

/// Tier-1 differential smoke: randomized hier scenarios through engine and
/// event loop, with the flat engine as exact-sum oracle — and the oracle
/// comparison must actually fire, not be skipped to vacuity.
#[test]
fn hier_differential_smoke_25_scenarios() {
    let report = run_hier_differential(0x41E2_0001, 25);
    assert_eq!(report.scenarios_run, 25);
    assert!(
        report.ok(),
        "{} mismatches; first: {:?}",
        report.failures.len(),
        report.failures.first()
    );
    assert!(report.oracle_compared > 0, "flat-oracle compare never fired in 25 scenarios");
}

/// The acceptance sweep for the CI hierarchical job (`--ignored`): ≥100
/// randomized scenarios, zero mismatches, with the flat-oracle comparison
/// firing on a healthy fraction.
#[test]
#[ignore = "hier differential sweep (~minutes): run explicitly — CI hierarchical job"]
fn hier_differential_acceptance_120_scenarios() {
    let report = run_hier_differential(0x41E2_1000, 120);
    assert_eq!(report.scenarios_run, 120);
    assert!(
        report.ok(),
        "{} mismatches; first: {:?}",
        report.failures.len(),
        report.failures.first()
    );
    assert!(
        report.oracle_compared >= 20,
        "oracle compared on only {}/120 scenarios",
        report.oracle_compared
    );
}

/// Per-shard churn storms for the CI hierarchical job (`--ignored`): the
/// rotating-storm campaign may drop shards, but must never disagree with
/// the plaintext truth or the per-level Theorem-1 predicate.
#[test]
#[ignore = "storm campaign (~tens of seconds): run explicitly — CI hierarchical job"]
fn storm_campaign_12_rounds_never_corrupts() {
    let scs = storm_scenarios(0x57012, 12, 60, 4);
    let rep = run_hier_campaign(&scs, Executor::EventLoop).unwrap();
    assert_eq!(rep.rounds, 12);
    assert_eq!(rep.truth_mismatches, 0, "a corrupted sum is a soundness bug");
    assert_eq!(rep.theorem1_disagreements, 0);
    assert!(rep.completed >= 10, "only {}/12 storm rounds completed", rep.completed);
}

/// CI scale job (`--ignored`, release): an n = 10⁵ hierarchical round over
/// 20 shards of 5000 on sparse degree-8 intra graphs completes, covers
/// ≥95% of the population and sums exactly — the stepping stone to the
/// n = 10⁶ bench row, which no flat round can reach at all.
#[test]
#[ignore = "scale smoke (~minutes unoptimized): run explicitly — CI scale-smoke job, release profile"]
fn hier_scale_smoke_n_100k() {
    let (n, shards, dim) = (100_000usize, 20usize, 32usize);
    let m = n / shards;
    let cfg = ProtocolConfig::builder()
        .clients(n)
        .threshold(3)
        .model_dim(dim)
        .topology(hier_topology(
            shards,
            Topology::ErdosRenyi { p: 8.0 / (m - 1) as f64 },
            Topology::Complete,
        ))
        .seed(0x5CA1E)
        .build()
        .unwrap();
    let models = models_for(n, dim, 6);
    let r = HierRunner::new(HierOptions {
        executor: Executor::EventLoop,
        check_truth: true,
        ..HierOptions::default()
    })
    .run(&cfg, &models)
    .unwrap();
    assert!(r.reliable);
    assert_eq!(r.sum, r.true_sum, "secure sum must equal the plaintext truth");
    assert!(
        r.global_v3.len() >= n * 95 / 100,
        "coverage {}/{n} below 95% (degree-8 withdrawal tail too fat)",
        r.global_v3.len()
    );
}
