//! SIGTERM/SIGINT handling for `ccesa serve`: a shutdown request makes the
//! server bail with the named "round interrupted, resumable" error, and the
//! journal it leaves behind really is resumable.
//!
//! Lives in its own integration binary because the shutdown flag is
//! process-global: triggering it next to other in-flight wire tests would
//! interrupt *their* servers too.

use ccesa::coordinator::{derive_round_setup, Executor, RoundOptions};
use ccesa::journal::{self, Journal};
use ccesa::net::socket::{self, INTERRUPTED};
use ccesa::protocol::Topology;
use ccesa::util::rng::Rng;
use ccesa::util::shutdown;
use std::net::TcpListener;
use std::time::Duration;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF).collect())
        .collect()
}

#[test]
fn shutdown_request_interrupts_the_server_with_the_named_resumable_error() {
    let n = 5;
    let dim = 4;
    let cfg = base(n, 3, dim, Topology::Complete, 0x516);
    let m = models(n, dim, 3);
    let dir = std::env::temp_dir().join(format!("ccesa-shutdown-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let round = socket::round_tag(cfg.seed);
    let setup = derive_round_setup(&cfg, &m);

    // installing the real handlers is safe and idempotent (the flag path
    // below is what they share with an actual SIGTERM)
    shutdown::install_handlers();
    shutdown::install_handlers();

    // a signal arrives before any client ever connects: the accept loop
    // must notice the flag instead of blocking out its whole timeout
    shutdown::trigger();
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let opts = RoundOptions::builder()
        .executor(Executor::Wire)
        .timeout(Duration::from_secs(30))
        .journal(dir.clone())
        .build()
        .unwrap();
    let err =
        socket::serve(&listener, &cfg, setup.plan, setup.graph, round, &opts).unwrap_err();
    shutdown::reset();
    assert!(
        err.to_string().contains(INTERRUPTED),
        "shutdown error must carry the named resumable message, got: {err:#}"
    );

    // the interrupted round is on disk and structurally resumable: the
    // setup record was fsynced before the first accept
    let rec = journal::recover(&Journal::path_for(&dir, round)).unwrap();
    assert_eq!(rec.round, round);
    assert_eq!(rec.next_phase, 0, "nothing was applied, so recovery restarts the round");
    assert!(rec.output.is_none());
    let _ = std::fs::remove_dir_all(&dir);
}
