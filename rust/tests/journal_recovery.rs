//! Round journal + crash recovery, end to end.
//!
//! Three layers of assurance:
//!
//! 1. **Corruption matrix** — a finished round's journal truncated at every
//!    byte offset, bit-flipped checksums, duplicated and out-of-order
//!    records: recovery either succeeds on the valid prefix or returns a
//!    named `JournalError`; it never panics and never double-counts.
//! 2. **In-process crash matrix** — `sim::crash` kills a journaled server
//!    at all seven phase boundaries, across every payload codec and three
//!    churn models, and requires the recovered round bit-identical to the
//!    uninterrupted engine (sums, survivor sets, logical `NetStats`).
//! 3. **Wire restart** — a real TCP server killed at phase boundaries via
//!    `StopAfter`, restarted on a *fresh port* with `serve_resume`, while
//!    `drive_clients_retry` clients reconnect with backoff and resubmit;
//!    the finished round must match the engine, including at n = 1000.

use ccesa::codec::Codec;
use ccesa::coordinator::{derive_round_setup, Executor, RoundOptions, RoundRunner, StopAfter};
use ccesa::journal::{self, Journal, JournalError, LogWriter, PREFIX_BYTES};
use ccesa::net::socket::{self, INTERRUPTED};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::sim::crash::{diff_crash_round, run_round_crashy, CrashPoint};
use ccesa::util::rng::Rng;
use std::net::{SocketAddr, TcpListener};
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ccesa-jrec-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A complete round's journal (all record types through FINAL), plus the
/// round it computed, for the corruption suites to mangle.
fn finished_journal(tag: &str) -> (PathBuf, PathBuf, u32, ccesa::coordinator::CoordRoundResult) {
    let n = 6;
    let dim = 4;
    let cfg = base(n, 3, dim, Topology::Complete, 0x1AB);
    let m = models(n, dim, 9);
    let dir = tmp_dir(tag);
    let opts = RoundOptions::builder().journal(&dir).build().unwrap();
    let r = RoundRunner::new(opts).run(&cfg, &m).unwrap();
    let round = socket::round_tag(cfg.seed);
    let path = Journal::path_for(&dir, round);
    (dir, path, round, r)
}

// ---------------------------------------------------------------------------
// 1. Corruption matrix
// ---------------------------------------------------------------------------

#[test]
fn truncation_at_every_byte_offset_recovers_or_errors_but_never_panics() {
    let (dir, path, round, _) = finished_journal("trunc");
    let bytes = std::fs::read(&path).unwrap();
    assert!(bytes.len() > 100, "journal suspiciously small: {} bytes", bytes.len());
    let work = dir.join("prefix.ccl");
    let mut last_phase = 0u8;
    for cut in 0..=bytes.len() {
        std::fs::write(&work, &bytes[..cut]).unwrap();
        match journal::recover(&work) {
            Ok(rec) => {
                assert_eq!(rec.round, round, "cut at {cut}");
                // longer valid prefixes never recover to an earlier phase
                assert!(
                    rec.next_phase >= last_phase,
                    "cut at {cut}: phase went backwards ({} < {last_phase})",
                    rec.next_phase
                );
                last_phase = rec.next_phase;
            }
            Err(e) => {
                // only the named pre-setup shapes may fail; anything else
                // is a torn tail and must recover on the valid prefix
                assert!(
                    matches!(e, JournalError::MissingSetup | JournalError::Malformed(_)),
                    "cut at {cut}: unexpected error {e}"
                );
            }
        }
    }
    assert_eq!(last_phase, 4, "the full journal must recover a finished round");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bit_flipped_record_bodies_are_named_checksum_errors() {
    let (dir, path, _round, _) = finished_journal("flip");
    let bytes = std::fs::read(&path).unwrap();
    let records = journal::read_log(&path).unwrap();
    assert!(records.len() >= 5, "expected a full round's records");
    // flip one body byte in every non-final record: scan must fail that
    // record's checksum, not misparse downstream records
    let work = dir.join("flipped.ccl");
    for rec in &records[..records.len() - 1] {
        let mut mangled = bytes.clone();
        let at = rec.offset as usize + PREFIX_BYTES;
        mangled[at] ^= 0x40;
        std::fs::write(&work, &mangled).unwrap();
        let err = journal::recover(&work).unwrap_err();
        assert!(
            matches!(err, JournalError::Checksum { .. }),
            "record at {}: expected checksum error, got {err}",
            rec.offset
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn duplicated_phase_batch_replays_idempotently_without_double_counting() {
    let (dir, path, round, baseline) = finished_journal("dup");
    let records = journal::read_log(&path).unwrap();
    // rebuild the journal with every record doubled in place — the replay
    // must treat each duplicate batch as the retransmission it is; the
    // FINAL cross-check record would name any double-counted sum
    let work = dir.join("doubled.ccl");
    let mut w = LogWriter::create(&work).unwrap();
    for rec in &records {
        w.append(rec.rec_type, rec.round, &rec.payload).unwrap();
        w.append(rec.rec_type, rec.round, &rec.payload).unwrap();
    }
    drop(w);
    let rec = journal::recover(&work).unwrap();
    assert_eq!(rec.round, round);
    assert_eq!(rec.next_phase, 4);
    let out = rec.output.expect("doubled journal still recovers the output");
    assert_eq!(out.sum, baseline.sum, "duplicate records must never change the sum");
    assert_eq!(out.sets, baseline.sets);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn out_of_order_and_skipped_records_are_named_replay_errors() {
    let (dir, path, _round, _) = finished_journal("order");
    let records = journal::read_log(&path).unwrap();
    // skip the phase-0 batch: setup straight to phase 1
    let work = dir.join("skipped.ccl");
    let mut w = LogWriter::create(&work).unwrap();
    w.append(records[0].rec_type, records[0].round, &records[0].payload).unwrap();
    w.append(records[2].rec_type, records[2].round, &records[2].payload).unwrap();
    drop(w);
    let err = journal::recover(&work).unwrap_err();
    assert!(matches!(err, JournalError::Replay(_)), "skip: expected replay error, got {err}");
    // replay an *old* batch after a later one (phase 1 then phase 0)
    let rewound = dir.join("rewound.ccl");
    let mut w = LogWriter::create(&rewound).unwrap();
    for rec in &records[..3] {
        w.append(rec.rec_type, rec.round, &rec.payload).unwrap();
    }
    w.append(records[1].rec_type, records[1].round, &records[1].payload).unwrap();
    drop(w);
    let err = journal::recover(&rewound).unwrap_err();
    assert!(matches!(err, JournalError::Replay(_)), "rewind: expected replay error, got {err}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 2. In-process crash matrix: every boundary × every codec × churn models
// ---------------------------------------------------------------------------

#[test]
fn crash_matrix_every_boundary_codec_and_churn_matches_engine() {
    let n = 10;
    let dim = 8;
    let m = models(n, dim, 0xC4A5);
    let churns: [(&str, DropoutModel); 3] = [
        ("steady", DropoutModel::None),
        (
            "midround",
            DropoutModel::Targeted { per_step: [vec![2], vec![5], vec![], vec![]] },
        ),
        (
            "every-step",
            DropoutModel::Targeted { per_step: [vec![1], vec![4], vec![7], vec![9]] },
        ),
    ];
    for (codec_name, codec) in [
        ("dense", Codec::Dense),
        ("topk", Codec::TopK { k: 3 }),
        ("randk", Codec::RandK { k: 3 }),
    ] {
        for (churn_name, dropout) in churns.clone() {
            let cfg = ProtocolConfig {
                codec: codec.clone(),
                dropout,
                ..base(n, 4, dim, Topology::ErdosRenyi { p: 0.9 }, 0xBEE5)
            };
            let dir = tmp_dir(&format!("matrix-{codec_name}-{churn_name}"));
            diff_crash_round(&cfg, &m, &dir)
                .unwrap_or_else(|e| panic!("{codec_name}/{churn_name}: {e:#}"));
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

#[test]
fn recovered_journal_is_itself_resumable_again() {
    // crash, recover, and the journal the recovered server kept appending
    // must itself recover to the same finished round (recovery composes)
    let n = 8;
    let dim = 6;
    let cfg = base(n, 3, dim, Topology::Complete, 0x2FA);
    let m = models(n, dim, 31);
    let dir = tmp_dir("compose");
    let r = run_round_crashy(&cfg, &m, &dir, CrashPoint::AfterStep1).unwrap();
    let rec = journal::recover(&Journal::path_for(&dir, socket::round_tag(cfg.seed))).unwrap();
    assert_eq!(rec.next_phase, 4);
    let out = rec.output.unwrap();
    assert_eq!(out.sum, r.sum);
    assert_eq!(out.sets, r.sets);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// 3. Wire restart: kill the TCP server, resume on a fresh port
// ---------------------------------------------------------------------------

/// Kill a journaled wire server at `point`, restart on a fresh ephemeral
/// port, and finish the round with the same retrying clients. Returns the
/// recovered round result.
fn wire_crash_restart(
    cfg: &ProtocolConfig,
    m: &[Vec<u64>],
    point: StopAfter,
    tag: &str,
) -> ccesa::coordinator::CoordRoundResult {
    let dir = tmp_dir(tag);
    let round = socket::round_tag(cfg.seed);
    let setup = derive_round_setup(cfg, m);
    let timeout = Duration::from_secs(120);

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr_cell = Arc::new(Mutex::new(listener.local_addr().unwrap()));

    let (srv_cfg, plan, graph, jdir) =
        (cfg.clone(), setup.plan.clone(), setup.graph.clone(), dir.clone());
    let server = std::thread::spawn(move || {
        let opts = RoundOptions::builder()
            .executor(Executor::Wire)
            .timeout(timeout)
            .journal(jdir)
            .stop_after(point)
            .build()
            .expect("wire round options");
        socket::serve(&listener, &srv_cfg, plan, graph, round, &opts)
    });

    let (cli_cfg, cli_models, cell) = (cfg.clone(), m.to_vec(), addr_cell.clone());
    let clients = std::thread::spawn(move || {
        let resolve = move || -> SocketAddr { *cell.lock().unwrap() };
        socket::drive_clients_retry(resolve, &cli_cfg, &cli_models, round, timeout)
    });

    // the injected crash: the server must die with the named resumable error
    let err = server.join().unwrap().unwrap_err();
    assert!(
        err.to_string().contains(INTERRUPTED),
        "{tag}: crash error not named resumable: {err:#}"
    );

    // restart on a *different* port; clients re-resolve and reconnect
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    *addr_cell.lock().unwrap() = listener.local_addr().unwrap();
    let path = Journal::path_for(&dir, round);
    let resume_opts = RoundOptions::builder()
        .executor(Executor::Wire)
        .timeout(timeout)
        .build()
        .expect("resume round options");
    let r = socket::serve_resume(&listener, &path, &resume_opts)
        .unwrap_or_else(|e| panic!("{tag}: resume failed: {e:#}"));
    clients.join().unwrap().unwrap_or_else(|e| panic!("{tag}: clients failed: {e:#}"));
    let _ = std::fs::remove_dir_all(&dir);
    r
}

#[test]
fn wire_server_killed_at_every_boundary_resumes_on_a_fresh_port() {
    let n = 12;
    let dim = 8;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step: [vec![3], vec![7], vec![], vec![]] },
        ..base(n, 4, dim, Topology::Complete, 0xD1E)
    };
    let m = models(n, dim, 77);
    let sync = run_round(&cfg, &m).unwrap();
    for (tag, point) in [
        ("setup", StopAfter::Setup),
        ("phase0", StopAfter::Phase(0)),
        ("phase1", StopAfter::Phase(1)),
        ("phase2", StopAfter::Phase(2)),
        ("phase3", StopAfter::Phase(3)),
    ] {
        let r = wire_crash_restart(&cfg, &m, point, &format!("wire-{tag}"));
        assert_eq!(r.sum, sync.sum, "{tag}: sum");
        assert_eq!(r.sets, sync.sets, "{tag}: survivor sets");
        assert_eq!(r.reliable, sync.reliable, "{tag}: reliable");
        // post-crash stats cover only resumed traffic, so no stats compare
    }
}

#[test]
fn thousand_client_wire_round_survives_a_mid_round_server_crash() {
    // the CI acceptance bar: n = 1000 over real loopback sockets, server
    // killed after routing shares (phase 1), resumed on a fresh port
    let n = 1000;
    let dim = 8;
    let cfg = base(n, 4, dim, Topology::Harary { k: 8 }, 0xFEED);
    let m = models(n, dim, 0xACE);
    let sync = run_round(&cfg, &m).unwrap();
    let r = wire_crash_restart(&cfg, &m, StopAfter::Phase(1), "wire-1k");
    assert_eq!(r.sum, sync.sum);
    assert_eq!(r.sets, sync.sets);
    assert_eq!(r.reliable, sync.reliable);
}
