//! The socket wire path against the in-process engine oracle.
//!
//! `net::socket::run_round_wire_opts` moves every protocol message over real
//! loopback TCP as `wire` frames; these suites pin it bit-identical to
//! `protocol::engine` — sums, survivor sets, and the logical (Appendix-C)
//! byte accounting — at four-digit client counts, under every payload
//! codec, under dropout at every step, and under a hostile network that
//! duplicates frames.

use ccesa::codec::Codec;
use ccesa::coordinator::{derive_round_setup, Executor, RoundOptions, TimeoutPolicy};
use ccesa::net::socket;
use ccesa::protocol::client::ClientSm;
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::messages::Down;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::rng::Rng;
use ccesa::wire;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

/// A wire round must match the engine on every observable except the
/// framed-byte counters, which must exist (and exceed the logical bytes —
/// framing is overhead, never compression).
fn assert_wire_matches_engine(cfg: &ProtocolConfig, m: &[Vec<u64>], label: &str) {
    let sync = run_round(cfg, m).unwrap();
    let wired = socket::run_round_wire_opts(cfg, m, &RoundOptions::default()).unwrap();
    assert_eq!(wired.reliable, sync.reliable, "{label}: reliable");
    assert_eq!(wired.sets, sync.sets, "{label}: survivor sets");
    assert_eq!(wired.sum, sync.sum, "{label}: sum");
    assert!(wired.stats.logical_eq(&sync.stats), "{label}: logical NetStats diverge");
    let logical_up: u64 = sync.stats.bytes_up.iter().sum();
    let logical_down: u64 = sync.stats.bytes_down.iter().sum();
    assert!(wired.stats.framed_up > logical_up, "{label}: framed_up must exceed logical");
    assert!(wired.stats.framed_down > logical_down, "{label}: framed_down must exceed logical");
}

#[test]
fn thousand_client_round_over_sockets_per_codec() {
    // the acceptance bar: a full round over real sockets at n = 1000,
    // bit-identical to the engine for every codec family
    let n = 1000;
    let dim = 32;
    let m = models(n, dim, 0xA11CE);
    for (label, codec) in [
        ("dense", Codec::Dense),
        ("topk", Codec::TopK { k: 8 }),
        ("randk", Codec::RandK { k: 8 }),
    ] {
        let cfg = ProtocolConfig {
            codec,
            ..base(n, 4, dim, Topology::Harary { k: 8 }, 0x31337)
        };
        assert_wire_matches_engine(&cfg, &m, label);
    }
}

#[test]
fn dropout_at_every_step_over_sockets_per_codec() {
    // clients vanish at every protocol step — including one that uploads
    // shares but never sends its masked input (s^SK reconstruction) — and
    // the wire path must still match the engine exactly
    let n = 40;
    let dim = 24;
    let m = models(n, dim, 0xD0D0);
    for (label, codec) in [
        ("dense", Codec::Dense),
        ("topk", Codec::TopK { k: 6 }),
        ("randk", Codec::RandK { k: 6 }),
    ] {
        let cfg = ProtocolConfig {
            codec,
            dropout: DropoutModel::Targeted {
                per_step: [vec![1], vec![5, 17], vec![9, 23], vec![13]],
            },
            ..base(n, 8, dim, Topology::ErdosRenyi { p: 0.6 }, 0x77AB)
        };
        assert_wire_matches_engine(&cfg, &m, label);
    }
}

#[test]
fn duplicated_wire_frames_do_not_disturb_honest_clients() {
    // a hand-rolled driver where client 0 sits behind a flaky network that
    // transmits every frame twice — Adv, Shares, Masked and Unmask are all
    // replayed byte-for-byte. The server must discard the duplicates
    // (frame-level phase check; the Server-layer dedup is the second line)
    // and the round must stay bit-identical to the in-process engine.
    let n = 3;
    let dim = 6;
    let cfg = base(n, 2, dim, Topology::Complete, 4242);
    let m = models(n, dim, 21);
    let sync = run_round(&cfg, &m).unwrap();

    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let round = socket::round_tag(cfg.seed);
    let setup = derive_round_setup(&cfg, &m);
    let (plan, graph) = (setup.plan.clone(), setup.graph.clone());
    let srv_cfg = cfg.clone();
    let opts = RoundOptions::builder()
        .executor(Executor::Wire)
        .timeout(Duration::from_secs(60))
        .build()
        .unwrap();
    let server = std::thread::spawn(move || {
        socket::serve(&listener, &srv_cfg, plan, graph, round, &opts)
    });

    let mut sms: Vec<ClientSm<'_>> = (0..n)
        .map(|id| {
            let (mut key_rng, share_rng) = setup.streams[id].clone();
            ClientSm::new(
                id,
                cfg.t,
                cfg.mask_bits,
                setup.graph.neighbors(id).to_vec(),
                &mut key_rng,
                share_rng,
                &m[id],
                setup.plan.clone(),
                setup.survives[id],
            )
        })
        .collect();
    let mut conns: Vec<Option<TcpStream>> =
        (0..n).map(|_| Some(TcpStream::connect(addr).unwrap())).collect();

    loop {
        let mut any_open = false;
        for id in 0..n {
            let Some(stream) = conns[id].as_mut() else { continue };
            any_open = true;
            match wire::read_frame(stream).unwrap() {
                None => {
                    conns[id] = None;
                }
                Some(body) => {
                    let (r, down) = wire::decode_down(&body).unwrap();
                    assert_eq!(r, round, "client {id}: round tag");
                    if matches!(down, Down::Finish) {
                        let _ = sms[id].step(Down::Finish);
                        conns[id] = None;
                        continue;
                    }
                    let frame = wire::encode_up(round, &sms[id].step(down));
                    let stream = conns[id].as_mut().unwrap();
                    stream.write_all(&frame).unwrap();
                    if id == 0 {
                        // the flaky network: replay the identical frame
                        stream.write_all(&frame).unwrap();
                    }
                    if sms[id].done() {
                        conns[id] = None;
                    }
                }
            }
        }
        if !any_open {
            break;
        }
    }

    let wired = server.join().unwrap().unwrap();
    assert_eq!(wired.reliable, sync.reliable);
    assert!(wired.reliable, "the duplicate-free baseline round is reliable");
    assert_eq!(wired.sets, sync.sets, "duplicates must not perturb survivor sets");
    assert_eq!(wired.sum, sync.sum, "duplicates must not double-count into the sum");
    assert!(wired.stats.logical_eq(&sync.stats), "duplicates must not be charged logically");
    let logical_up: u64 = sync.stats.bytes_up.iter().sum();
    assert!(wired.stats.framed_up > logical_up, "the duplicates do hit the socket counter");
}

/// Drive `cfg.n` honest socket clients against a policy-carrying server,
/// each on its own thread. `stall(id, down)` returning true makes that
/// client sleep `stall_for` *after* computing its answer — from the
/// server's side it is connected but silent, exactly the straggler the
/// per-phase deadline exists to cut. Write failures and mid-round EOF are
/// tolerated: that is what being timed out looks like from the client.
fn drive_with_straggler(
    cfg: &ProtocolConfig,
    m: &[Vec<u64>],
    opts: &RoundOptions,
    stall: impl Fn(usize, &Down) -> bool + Sync,
    stall_for: Duration,
) -> ccesa::coordinator::CoordRoundResult {
    let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
    let addr = listener.local_addr().unwrap();
    let round = socket::round_tag(cfg.seed);
    let setup = derive_round_setup(cfg, m);
    let (plan, graph) = (setup.plan.clone(), setup.graph.clone());
    let stall = &stall;
    let setup = &setup;
    std::thread::scope(|s| {
        let server =
            s.spawn(|| socket::serve(&listener, cfg, plan.clone(), graph.clone(), round, opts));
        for id in 0..cfg.n {
            s.spawn(move || {
                let (mut key_rng, share_rng) = setup.streams[id].clone();
                let mut sm = ClientSm::new(
                    id,
                    cfg.t,
                    cfg.mask_bits,
                    setup.graph.neighbors(id).to_vec(),
                    &mut key_rng,
                    share_rng,
                    &m[id],
                    setup.plan.clone(),
                    setup.survives[id],
                );
                let mut stream = TcpStream::connect(addr).unwrap();
                loop {
                    let body = match wire::read_frame(&mut stream) {
                        Ok(Some(b)) => b,
                        // EOF / reset: the server cut us (or the round is over)
                        _ => break,
                    };
                    let (r, down) = wire::decode_down(&body).unwrap();
                    assert_eq!(r, round, "client {id}: round tag");
                    if matches!(down, Down::Finish) {
                        let _ = sm.step(Down::Finish);
                        break;
                    }
                    let stalled = stall(id, &down);
                    let frame = wire::encode_up(round, &sm.step(down));
                    if stalled {
                        std::thread::sleep(stall_for);
                    }
                    if stream.write_all(&frame).is_err() {
                        break; // already disconnected by the phase deadline
                    }
                    if sm.done() {
                        break;
                    }
                }
            });
        }
        server.join().unwrap().unwrap()
    })
}

/// A per-phase deadline on the wire cuts a connected-but-silent straggler
/// exactly like the virtual clock does: the round finishes without it, the
/// drop lands in `timeout_drops`/`timeline` under the right phase, and the
/// result is bit-identical to the engine with that client churned at the
/// same step.
#[test]
fn wire_phase_deadline_cuts_a_masked_phase_straggler() {
    let n = 6;
    let dim = 6;
    let straggler = 5usize;
    let cfg = base(n, 3, dim, Topology::Complete, 0x57A11);
    let m = models(n, dim, 0x57A11);
    // generous everywhere except the masked phase; the grace floor of
    // n − 1 keeps CI jitter from ever cutting a prompt client
    let policy = TimeoutPolicy {
        per_phase_deadlines: [
            Duration::from_secs(30),
            Duration::from_secs(30),
            Duration::from_millis(200),
            Duration::from_secs(30),
        ],
        min_survivors: n - 1,
    };
    let opts = RoundOptions::builder()
        .executor(Executor::Wire)
        .timeout(Duration::from_secs(60))
        .timeout_policy(policy)
        .build()
        .unwrap();
    let wired = drive_with_straggler(
        &cfg,
        &m,
        &opts,
        |id, down| id == straggler && matches!(down, Down::Delivery(_)),
        Duration::from_secs(3),
    );

    assert_eq!(wired.stats.timeout_drops, [0, 0, 1, 0]);
    let tl = wired.timeline.as_ref().expect("a policy-carrying round reports its timeline");
    assert_eq!(tl.dropped[2], vec![straggler], "the straggler is named under its phase");
    assert!(
        tl.phase_elapsed_us[2] >= 200_000,
        "the masked phase sat out its deadline: {} µs",
        tl.phase_elapsed_us[2]
    );
    assert!(wired.reliable, "n − 1 survivors ≥ t: the round succeeds without the straggler");
    assert!(wired.sets.v2.contains(&straggler), "shares landed on time");
    assert!(!wired.sets.v3.contains(&straggler), "cut before masked input");
    assert_eq!(wired.sets.v3.len(), n - 1);

    // the engine with {straggler} churned at the masked step is the
    // reference — same claim the clocked differential makes, on real TCP
    let ref_cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![], vec![], vec![straggler], vec![]],
        },
        ..cfg.clone()
    };
    let mut sync = run_round(&ref_cfg, &m).unwrap();
    sync.stats.timeout_drops = [0, 0, 1, 0]; // the engine has no clock to classify with
    assert_eq!(wired.sets, sync.sets, "timeout drop must equal churn: survivor sets");
    assert_eq!(wired.sum, sync.sum, "timeout drop must equal churn: sum");
    assert!(wired.stats.logical_eq(&sync.stats), "timeout drop must equal churn: NetStats");
}

/// Generous per-phase deadlines are inert: nobody is cut, the timeline is
/// still reported, and the round matches the policy-free engine exactly.
#[test]
fn wire_generous_phase_deadlines_drop_no_one() {
    let n = 8;
    let dim = 10;
    let cfg = base(n, 3, dim, Topology::ErdosRenyi { p: 0.8 }, 0x57A22);
    let m = models(n, dim, 0x57A22);
    let opts = RoundOptions::builder()
        .executor(Executor::Wire)
        .timeout(Duration::from_secs(60))
        .timeout_policy(TimeoutPolicy::uniform(Duration::from_secs(30)))
        .build()
        .unwrap();
    let wired = drive_with_straggler(&cfg, &m, &opts, |_, _| false, Duration::ZERO);

    assert_eq!(wired.stats.timeout_drops, [0; 4]);
    let tl = wired.timeline.as_ref().expect("policy ⇒ timeline");
    assert!(!tl.dropped_any());
    assert!(tl.total_us() > 0, "wall-clock phase timings are recorded");

    let sync = run_round(&cfg, &m).unwrap();
    assert_eq!(wired.sets, sync.sets);
    assert_eq!(wired.sum, sync.sum);
    assert!(wired.stats.logical_eq(&sync.stats));
}
