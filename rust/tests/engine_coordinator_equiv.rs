//! Determinism regression suite: `engine::run_round` and the threaded
//! `coordinator` must produce bit-identical `RoundResult` essentials (sum,
//! survivor sets, NetStats) for the same seed under rng-free dropout
//! models, exactly as the coordinator module docs promise — and each driver
//! must be bit-identical to itself across reruns.

use ccesa::coordinator::run_round_threaded;
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::rng::Rng;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

fn assert_equivalent(cfg: &ProtocolConfig, m: &[Vec<u64>], label: &str) {
    let sync = run_round(cfg, m).unwrap();
    let threaded = run_round_threaded(cfg, m).unwrap();
    assert_eq!(threaded.reliable, sync.reliable, "{label}: reliable");
    assert_eq!(threaded.sets, sync.sets, "{label}: survivor sets");
    assert_eq!(threaded.sum, sync.sum, "{label}: sum");
    assert_eq!(threaded.stats, sync.stats, "{label}: NetStats");
}

#[test]
fn bit_identical_no_dropout_across_topologies() {
    let n = 14;
    let dim = 24;
    let m = models(n, dim, 11);
    for (label, topology) in [
        ("complete", Topology::Complete),
        ("er", Topology::ErdosRenyi { p: 0.75 }),
        ("harary", Topology::Harary { k: 6 }),
    ] {
        let cfg = ProtocolConfig::new(n, 5, dim, topology, 3001);
        assert_equivalent(&cfg, &m, label);
    }
}

#[test]
fn bit_identical_under_targeted_dropout() {
    let n = 12;
    let dim = 10;
    let m = models(n, dim, 12);
    // dropouts at every step, including one client that uploads shares but
    // never sends its masked input (the s^SK reconstruction path)
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![0], vec![4], vec![7, 8], vec![11]],
        },
        ..ProtocolConfig::new(n, 4, dim, Topology::ErdosRenyi { p: 0.85 }, 3002)
    };
    assert_equivalent(&cfg, &m, "targeted");
}

#[test]
fn bit_identical_under_materialized_iid() {
    // a stochastic model becomes driver-independent once materialized —
    // the mechanism the sim scenario compiler relies on
    let n = 13;
    let dim = 8;
    let m = models(n, dim, 13);
    let iid = DropoutModel::Iid { q: 0.12 };
    let per_step = iid.materialize(n, &mut Rng::new(0xAB));
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step },
        ..ProtocolConfig::new(n, 4, dim, Topology::ErdosRenyi { p: 0.9 }, 3003)
    };
    assert_equivalent(&cfg, &m, "materialized-iid");
}

#[test]
fn engine_rerun_is_bit_identical() {
    let n = 10;
    let dim = 16;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step: [vec![], vec![2], vec![5], vec![]] },
        ..ProtocolConfig::new(n, 4, dim, Topology::ErdosRenyi { p: 0.8 }, 3004)
    };
    let m = models(n, dim, 14);
    let a = run_round(&cfg, &m).unwrap();
    let b = run_round(&cfg, &m).unwrap();
    assert_eq!(a.sum, b.sum);
    assert_eq!(a.sets, b.sets);
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.reliable, b.reliable);
    assert_eq!(a.theorem1_holds, b.theorem1_holds);
    assert_eq!(a.true_sum_v3, b.true_sum_v3);
    // the adversary's view is identical too: same keys, same ciphertext
    // metadata, same revealed shares
    assert_eq!(a.transcript.keys, b.transcript.keys);
    assert_eq!(a.transcript.masked, b.transcript.masked);
    assert_eq!(a.transcript.unmask_shares, b.transcript.unmask_shares);
}

#[test]
fn coordinator_rerun_is_bit_identical() {
    let n = 11;
    let dim = 12;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step: [vec![1], vec![], vec![6], vec![9]] },
        ..ProtocolConfig::new(n, 4, dim, Topology::Complete, 3005)
    };
    let m = models(n, dim, 15);
    let a = run_round_threaded(&cfg, &m).unwrap();
    let b = run_round_threaded(&cfg, &m).unwrap();
    assert_eq!(a.sum, b.sum);
    assert_eq!(a.sets, b.sets);
    assert_eq!(a.stats, b.stats);
}

#[test]
fn both_drivers_abort_identically() {
    // |V2| < t after mass step-1 dropout: the engine errors; the
    // coordinator must error too (and terminate — regression for the
    // worker-unblocking fix) rather than deadlock or return a result
    let n = 8;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![], (0..6).collect(), vec![], vec![]],
        },
        ..ProtocolConfig::new(n, 5, 6, Topology::Complete, 3006)
    };
    let m = models(n, 6, 16);
    assert!(run_round(&cfg, &m).is_err(), "engine must abort");
    assert!(run_round_threaded(&cfg, &m).is_err(), "coordinator must abort");
}

#[test]
fn sixteen_and_sixty_four_bit_domains_equivalent() {
    let n = 9;
    let dim = 7;
    for bits in [16u32, 64] {
        let mut cfg = ProtocolConfig::new(n, 4, dim, Topology::Complete, 3007);
        cfg.mask_bits = bits;
        let modmask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut rng = Rng::new(17);
        let m: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & modmask).collect())
            .collect();
        assert_equivalent(&cfg, &m, &format!("bits={bits}"));
    }
}
