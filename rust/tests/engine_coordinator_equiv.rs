//! Determinism regression suite: `engine::run_round` and the worker-pool
//! event loop must produce bit-identical `RoundResult` essentials (sum,
//! survivor sets, NetStats) for the same seed under rng-free dropout
//! models and every payload codec, exactly as the coordinator module docs
//! promise — and each execution shape must be bit-identical to itself
//! across reruns. The event loop additionally proves the scaling claim:
//! rounds at n = 10⁴ (tier-1) and n = 10⁵ (CI scale job, `--ignored`,
//! dense and RandK) complete with peak live pool workers ≤
//! `par::threads()`.

use ccesa::codec::Codec;
use ccesa::coordinator::{CoordRoundResult, RoundOptions, RoundRunner};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::util::rng::Rng;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

fn assert_equivalent(cfg: &ProtocolConfig, m: &[Vec<u64>], label: &str) {
    let sync = run_round(cfg, m).unwrap();
    let check = |name: &str, r: CoordRoundResult| {
        assert_eq!(r.reliable, sync.reliable, "{label}/{name}: reliable");
        assert_eq!(r.sets, sync.sets, "{label}/{name}: survivor sets");
        assert_eq!(r.sum, sync.sum, "{label}/{name}: sum");
        assert_eq!(r.stats, sync.stats, "{label}/{name}: NetStats");
    };
    check("event-loop", RoundRunner::new(RoundOptions::default()).run(cfg, m).unwrap());
}

/// Event-loop round with an explicit worker count, returning telemetry.
fn event_loop_with(
    cfg: &ProtocolConfig,
    m: &[Vec<u64>],
    workers: usize,
) -> anyhow::Result<(CoordRoundResult, ccesa::coordinator::LoopTelemetry)> {
    let opts = RoundOptions::builder().workers(workers).build()?;
    RoundRunner::new(opts).run_with_telemetry(cfg, m)
}

#[test]
fn bit_identical_no_dropout_across_topologies() {
    let n = 14;
    let dim = 24;
    let m = models(n, dim, 11);
    for (label, topology) in [
        ("complete", Topology::Complete),
        ("er", Topology::ErdosRenyi { p: 0.75 }),
        ("harary", Topology::Harary { k: 6 }),
    ] {
        let cfg = base(n, 5, dim, topology, 3001);
        assert_equivalent(&cfg, &m, label);
    }
}

#[test]
fn bit_identical_under_targeted_dropout() {
    let n = 12;
    let dim = 10;
    let m = models(n, dim, 12);
    // dropouts at every step, including one client that uploads shares but
    // never sends its masked input (the s^SK reconstruction path)
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![0], vec![4], vec![7, 8], vec![11]],
        },
        ..base(n, 4, dim, Topology::ErdosRenyi { p: 0.85 }, 3002)
    };
    assert_equivalent(&cfg, &m, "targeted");
}

#[test]
fn bit_identical_under_materialized_iid() {
    // a stochastic model becomes shape-independent once materialized —
    // the mechanism the sim scenario compiler relies on
    let n = 13;
    let dim = 8;
    let m = models(n, dim, 13);
    let iid = DropoutModel::Iid { q: 0.12 };
    let per_step = iid.materialize(n, &mut Rng::new(0xAB));
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step },
        ..base(n, 4, dim, Topology::ErdosRenyi { p: 0.9 }, 3003)
    };
    assert_equivalent(&cfg, &m, "materialized-iid");
}

#[test]
fn bit_identical_across_codecs_with_dropout() {
    // the codec axis × dropout: every payload family must agree between
    // the engine and the event loop, including the s^SK-reconstruction
    // path masking only k packed positions
    let n = 12;
    let dim = 30;
    let m = models(n, dim, 14);
    for (label, codec) in [
        ("dense", Codec::Dense),
        ("topk", Codec::TopK { k: 6 }),
        ("randk", Codec::RandK { k: 6 }),
    ] {
        let cfg = ProtocolConfig {
            codec,
            dropout: DropoutModel::Targeted {
                per_step: [vec![1], vec![], vec![5, 9], vec![2]],
            },
            ..base(n, 4, dim, Topology::ErdosRenyi { p: 0.9 }, 3008)
        };
        assert_equivalent(&cfg, &m, label);
    }
}

#[test]
fn engine_rerun_is_bit_identical() {
    let n = 10;
    let dim = 16;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step: [vec![], vec![2], vec![5], vec![]] },
        ..base(n, 4, dim, Topology::ErdosRenyi { p: 0.8 }, 3004)
    };
    let m = models(n, dim, 14);
    let a = run_round(&cfg, &m).unwrap();
    let b = run_round(&cfg, &m).unwrap();
    assert_eq!(a.sum, b.sum);
    assert_eq!(a.sets, b.sets);
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.reliable, b.reliable);
    assert_eq!(a.theorem1_holds, b.theorem1_holds);
    assert_eq!(a.true_sum_v3, b.true_sum_v3);
    // the adversary's view is identical too: same keys, same ciphertext
    // metadata, same revealed shares
    assert_eq!(a.transcript.keys, b.transcript.keys);
    assert_eq!(a.transcript.masked, b.transcript.masked);
    assert_eq!(a.transcript.unmask_shares, b.transcript.unmask_shares);
}

#[test]
fn event_loop_rerun_is_bit_identical_across_worker_counts() {
    // rerun stability AND worker-count independence: the lane sharding
    // must be invisible in every observable
    let n = 11;
    let dim = 12;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted { per_step: [vec![1], vec![], vec![6], vec![9]] },
        ..base(n, 4, dim, Topology::Complete, 3005)
    };
    let m = models(n, dim, 15);
    let (a, _) = event_loop_with(&cfg, &m, 1).unwrap();
    for workers in [2usize, 3, 8] {
        let (b, tel) = event_loop_with(&cfg, &m, workers).unwrap();
        assert_eq!(a.sum, b.sum, "workers={workers}");
        assert_eq!(a.sets, b.sets, "workers={workers}");
        assert_eq!(a.stats, b.stats, "workers={workers}");
        assert!(tel.peak_live_workers <= workers, "workers={workers}");
        assert_eq!(tel.sweeps, 4, "workers={workers}");
    }
}

#[test]
fn both_shapes_abort_identically() {
    // |V2| < t after mass step-1 dropout: the engine errors; the event
    // loop must error too
    let n = 8;
    let cfg = ProtocolConfig {
        dropout: DropoutModel::Targeted {
            per_step: [vec![], (0..6).collect(), vec![], vec![]],
        },
        ..base(n, 5, 6, Topology::Complete, 3006)
    };
    let m = models(n, 6, 16);
    assert!(run_round(&cfg, &m).is_err(), "engine must abort");
    let runner = RoundRunner::new(RoundOptions::default());
    assert!(runner.run(&cfg, &m).is_err(), "event loop must abort");
}

#[test]
fn sixteen_and_sixty_four_bit_domains_equivalent() {
    let n = 9;
    let dim = 7;
    for bits in [16u32, 64] {
        let mut cfg = base(n, 4, dim, Topology::Complete, 3007);
        cfg.mask_bits = bits;
        let modmask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        let mut rng = Rng::new(17);
        let m: Vec<Vec<u64>> = (0..n)
            .map(|_| (0..dim).map(|_| rng.next_u64() & modmask).collect())
            .collect();
        assert_equivalent(&cfg, &m, &format!("bits={bits}"));
    }
}

/// Exact expected no-dropout sum: Σ models over all n clients in Z_{2^32}.
fn true_sum_all(m: &[Vec<u64>], dim: usize) -> Vec<u64> {
    let mut expect = vec![0u64; dim];
    for mv in m {
        for (a, x) in expect.iter_mut().zip(mv) {
            *a = a.wrapping_add(*x) & 0xFFFF_FFFF;
        }
    }
    expect
}

/// Tier-1 scale smoke: one n = 10⁴ event-loop round — two orders of
/// magnitude past the differential suite's population, still inside the
/// tier-1 budget because thread cost is O(par::threads()), not O(n).
#[test]
fn event_loop_n10k_single_round_smoke() {
    let n = 10_000;
    let dim = 4;
    let cfg = base(n, 3, dim, Topology::Harary { k: 6 }, 41);
    let m = models(n, dim, 42);
    let workers = ccesa::par::threads();
    let (r, tel) = event_loop_with(&cfg, &m, workers).unwrap();
    assert!(r.reliable);
    assert_eq!(r.sets.v4.len(), n);
    assert_eq!(r.sum.unwrap(), true_sum_all(&m, dim));
    assert!(
        tel.peak_live_workers <= workers,
        "peak {} workers exceeds budget {workers}",
        tel.peak_live_workers
    );
    assert_eq!(tel.sweeps, 4);
}

/// CI scale job (`--ignored`): a n = 10⁵-client round completes on a fixed
/// worker pool — the regime where complete-graph SA costs diverge from the
/// sparse Erdős–Rényi scheme, and where a thread-per-client shape would
/// need 10⁵ OS threads.
#[test]
#[ignore = "scale smoke (~minutes unoptimized): run explicitly — CI scale-smoke job, release profile"]
fn event_loop_n100k_round_completes_with_bounded_threads() {
    let n = 100_000;
    let dim = 4;
    let cfg = base(n, 3, dim, Topology::Harary { k: 6 }, 43);
    let m = models(n, dim, 44);
    let workers = ccesa::par::threads();
    let (r, tel) = event_loop_with(&cfg, &m, workers).unwrap();
    assert!(r.reliable);
    assert_eq!(r.sets.v4.len(), n);
    assert_eq!(r.sum.unwrap(), true_sum_all(&m, dim));
    assert!(
        tel.peak_live_workers <= workers,
        "peak {} workers exceeds budget {workers}",
        tel.peak_live_workers
    );
    assert_eq!(tel.sweeps, 4);
    println!(
        "n=100000 round: workers={} peak_live={} sweeps={}",
        tel.workers, tel.peak_live_workers, tel.sweeps
    );
}

/// CI scale job (`--ignored`), sparse leg: the same n = 10⁵ round under a
/// RandK payload — the masked upload shrinks 4× while the aggregate still
/// equals the projected true sum, with the same bounded-thread guarantee.
#[test]
#[ignore = "scale smoke (~minutes unoptimized): run explicitly — CI scale-smoke job, release profile"]
fn event_loop_n100k_randk_round_completes_with_bounded_threads() {
    let n = 100_000;
    let dim = 8;
    let k = 2;
    let cfg = ProtocolConfig {
        codec: Codec::RandK { k },
        ..base(n, 3, dim, Topology::Harary { k: 6 }, 45)
    };
    let m = models(n, dim, 46);
    let workers = ccesa::par::threads();
    let (r, tel) = event_loop_with(&cfg, &m, workers).unwrap();
    assert!(r.reliable);
    assert_eq!(r.sets.v4.len(), n);
    // projected true sum: dense sum restricted to the round's support
    let plan = cfg.codec.plan(dim, cfg.mask_bits, cfg.seed, &m);
    let mut expect = true_sum_all(&m, dim);
    plan.project(&mut expect);
    assert_eq!(r.sum.unwrap(), expect);
    // payload bytes: |V3| · k · 4 instead of |V3| · dim · 4
    assert_eq!(r.stats.masked_payload_bytes, (n * k * 4) as u64);
    assert!(
        tel.peak_live_workers <= workers,
        "peak {} workers exceeds budget {workers}",
        tel.peak_live_workers
    );
    println!(
        "n=100000 randk round: workers={} peak_live={} payload_bytes={}",
        tel.workers, tel.peak_live_workers, r.stats.masked_payload_bytes
    );
}
