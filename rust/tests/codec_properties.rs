//! Codec-layer acceptance suite.
//!
//! Pins the three contracts of the `UpdateCodec` redesign:
//! 1. **Dense bit-compatibility** — the identity codec reproduces the
//!    pre-redesign dense protocol exactly: same wire bytes per step
//!    (golden numbers derived from the Appendix-C size model), same
//!    aggregate (= the plaintext V3 sum oracle, which the pre-redesign
//!    engine also equalled — transitivity gives bit-identical sums).
//! 2. **Sparse round-trips** — TopK/RandK rounds recover exactly the
//!    projected V3 sum, under dropout at every step, on both executors.
//! 3. **Measured savings** — TopK at k = 0.1·dim cuts the masked-payload
//!    bytes ≥5× in `NetStats` while the differential harness reports zero
//!    engine/event-loop mismatches.

use ccesa::codec::{Codec, IndexPlan};
use ccesa::coordinator::{RoundOptions, RoundRunner};
use ccesa::protocol::dropout::DropoutModel;
use ccesa::protocol::engine::run_round;
use ccesa::protocol::{ProtocolConfig, Topology};
use ccesa::sim::{
    run_differential, AdversarySpec, ChurnModel, CodecSpec, DiffSpec, Scenario,
    ThresholdRule, TopologySchedule,
};
use ccesa::util::rng::Rng;

mod common;
use common::base;

fn models(n: usize, dim: usize, seed: u64) -> Vec<Vec<u64>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..dim).map(|_| rng.next_u64() & 0xFFFF_FFFF).collect())
        .collect()
}

/// The dense codec's wire contract, pinned against the pre-redesign byte
/// model: n = 4 clients, complete graph, dim = 8, b = 32, no dropout.
/// Every per-step total is computed from first principles (Appendix C
/// sizes: a_K = 32, a_S = 34, 4-byte ids, 16-byte AEAD tags) — exactly
/// the numbers the engine charged before the codec layer existed.
#[test]
fn dense_codec_matches_pre_redesign_wire_contract() {
    let n = 4;
    let dim = 8;
    let cfg = base(n, 2, dim, Topology::Complete, 0xD0C);
    let m = models(n, dim, 1);
    let r = run_round(&cfg, &m).unwrap();
    assert!(r.reliable);

    // step 0: 4 × (4 + 2·32) up; 4 × 3 neighbors × (4 + 2·32) down
    assert_eq!(r.stats.bytes_up[0], 272);
    assert_eq!(r.stats.bytes_down[0], 816);
    // step 1: ciphertext = 2 (len prefix) + 2·34 (shares) + 16 (tag) = 86;
    // per EncryptedShare 8 + 86 = 94; per client 4 + 3·94 = 286
    assert_eq!(r.stats.bytes_up[1], 4 * 286);
    assert_eq!(r.stats.bytes_down[1], 4 * 286);
    // step 2: masked input = 4 + 8·4 = 36 per client; announce 16 × 4
    assert_eq!(r.stats.bytes_up[2], 4 * 36);
    assert_eq!(r.stats.bytes_down[2], 64);
    assert_eq!(r.stats.masked_payload_bytes, 4 * 32);
    // step 3: 4 SelfMask shares per client × (4 + 1 + 34) + 4-byte id
    assert_eq!(r.stats.bytes_up[3], 4 * 160);
    assert_eq!(r.stats.bytes_down[3], 0);

    // and the aggregate is the exact plaintext V3 sum — the same oracle
    // the pre-redesign engine equalled, so sums are bit-identical
    assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3);
    // dense transcript payload is the full model dimension
    assert_eq!(r.transcript.payload_len, dim);
}

/// Sparse codecs change Step-2 traffic only: every other step's bytes are
/// byte-identical to the dense round on the same config.
#[test]
fn sparse_codec_changes_only_step2_traffic() {
    let n = 4;
    let dim = 8;
    let k = 2;
    let m = models(n, dim, 1);
    let dense = run_round(&base(n, 2, dim, Topology::Complete, 0xD0C), &m).unwrap();
    let cfg = ProtocolConfig {
        codec: Codec::RandK { k },
        ..base(n, 2, dim, Topology::Complete, 0xD0C)
    };
    let sparse = run_round(&cfg, &m).unwrap();
    for step in [0usize, 1, 3] {
        assert_eq!(sparse.stats.bytes_up[step], dense.stats.bytes_up[step], "step {step}");
        assert_eq!(sparse.stats.bytes_down[step], dense.stats.bytes_down[step], "step {step}");
    }
    assert_eq!(sparse.stats.bytes_down[2], dense.stats.bytes_down[2], "announce unchanged");
    // masked upload shrinks from 4 + 32 to 4 + 8 per client
    assert_eq!(sparse.stats.bytes_up[2], 4 * (4 + k as u64 * 4));
    assert_eq!(sparse.stats.masked_payload_bytes, 4 * k as u64 * 4);
}

/// TopK/RandK round-trip property: across seeds and dropout patterns, a
/// reliable sparse round recovers exactly the projected V3 sum, the
/// off-support coordinates are zero, and the event loop agrees with the
/// engine bit for bit.
#[test]
fn sparse_round_trip_survives_dropout_across_seeds() {
    let n = 10;
    let dim = 24;
    let k = 6;
    let mut reliable_seen = 0usize;
    for seed in 0..12u64 {
        // materialize the stochastic dropout into an explicit schedule:
        // engine/event-loop bit-identity is promised for rng-free models
        // (their lazy-vs-predrawn Iid streams diverge once anyone drops)
        let per_step =
            DropoutModel::Iid { q: 0.08 }.materialize(n, &mut Rng::new(0xD201 + seed));
        for codec in [Codec::TopK { k }, Codec::RandK { k }] {
            let cfg = ProtocolConfig {
                codec,
                dropout: DropoutModel::Targeted { per_step: per_step.clone() },
                ..base(n, 3, dim, Topology::ErdosRenyi { p: 0.85 }, 7000 + seed)
            };
            let m = models(n, dim, seed);
            let runner = RoundRunner::new(RoundOptions::default());
            let (engine, looped) = (run_round(&cfg, &m), runner.run(&cfg, &m));
            match (engine, looped) {
                (Ok(e), Ok(l)) => {
                    assert_eq!(e.sum, l.sum, "seed={seed} {codec:?}");
                    assert_eq!(e.sets, l.sets, "seed={seed} {codec:?}");
                    assert_eq!(e.stats, l.stats, "seed={seed} {codec:?}");
                    if e.reliable {
                        reliable_seen += 1;
                        let sum = e.sum.as_ref().unwrap();
                        assert_eq!(sum, &e.true_sum_v3, "seed={seed} {codec:?}");
                        let plan = cfg.codec.plan(dim, cfg.mask_bits, cfg.seed, &m);
                        let support = plan.indices().unwrap();
                        for (j, w) in sum.iter().enumerate() {
                            if !support.contains(&(j as u32)) {
                                assert_eq!(*w, 0, "seed={seed} {codec:?} coord {j}");
                            }
                        }
                    }
                }
                (Err(_), Err(_)) => {} // agreed abort under churn
                (e, l) => panic!("executors disagree on abort: seed={seed} {e:?} vs {l:?}"),
            }
        }
    }
    assert!(reliable_seen >= 8, "too few reliable sparse rounds ({reliable_seen})");
}

/// Dropout between Steps 1 and 2 forces the s^SK reconstruction path:
/// pairwise masks must cancel inside the packed domain too.
#[test]
fn sparse_codec_cancels_pairwise_masks_of_dropped_clients() {
    let n = 10;
    let dim = 40;
    for codec in [Codec::TopK { k: 9 }, Codec::RandK { k: 9 }] {
        let cfg = ProtocolConfig {
            codec,
            dropout: DropoutModel::Targeted {
                per_step: [vec![], vec![], vec![2, 5], vec![]],
            },
            ..base(n, 4, dim, Topology::Complete, 99)
        };
        let m = models(n, dim, 9);
        let r = run_round(&cfg, &m).unwrap();
        assert!(r.reliable, "{codec:?}");
        assert_eq!(r.sets.v3.len(), n - 2, "{codec:?}");
        assert_eq!(r.sum.as_ref().unwrap(), &r.true_sum_v3, "{codec:?}");
    }
}

/// The headline acceptance criterion: a TopK(k = 0.1·dim) scenario cuts
/// masked-payload bytes ≥5× vs dense in `NetStats`, and the differential
/// harness reports zero engine/event-loop mismatches on that scenario.
#[test]
fn topk_ten_percent_saves_5x_payload_with_zero_mismatches() {
    let n = 20;
    let dim = 500;
    let mk = |codec: CodecSpec| Scenario {
        name: format!("savings-{}", codec.name()),
        n,
        dim,
        mask_bits: 32,
        rounds: 2,
        topology: TopologySchedule::Static(Topology::ErdosRenyi { p: 0.7 }),
        churn: ChurnModel::Iid { q: 0.03 },
        adversary: AdversarySpec::Eavesdropper,
        threshold: ThresholdRule::Fixed(6),
        codec,
        clip: 4.0,
        seed: 0x5A7E_5A5A,
    };
    let dense = mk(CodecSpec::Dense);
    let topk = mk(CodecSpec::TopK { frac: 0.1 });

    // zero mismatches between the executors on the sparse scenario
    assert!(run_differential(&DiffSpec::Flat(&topk)).is_none(), "sparse differential mismatch");
    assert!(run_differential(&DiffSpec::Flat(&dense)).is_none(), "dense differential mismatch");

    // measured payload bytes: ≥5× saving (10× exactly at frac = 0.1) —
    // one campaign per scenario provides both byte counters
    let run = |sc: &Scenario| {
        let rep = ccesa::sim::run_campaign(sc, ccesa::sim::Executor::Engine).unwrap();
        assert!(rep.reliable_rounds() >= 1, "{}", sc.name);
        (rep.total_stats.masked_payload_bytes, rep.total_stats.bytes_up[2])
    };
    let (dense_payload, dense_up2) = run(&dense);
    let (topk_payload, topk_up2) = run(&topk);
    assert!(topk_payload > 0);
    assert!(
        dense_payload >= 5 * topk_payload,
        "payload saving below 5x: dense={dense_payload} topk={topk_payload}"
    );
    // the full Step-2 uplink (ids included) also clears 5×
    assert!(dense_up2 >= 5 * topk_up2, "uplink saving below 5x: {dense_up2} vs {topk_up2}");
}

/// Plan algebra round-trip over random sparse plans and bit widths:
/// scatter ∘ encode equals projection, for any dense vector.
#[test]
fn plan_roundtrip_property_random_plans() {
    let mut rng = Rng::new(0xB10B);
    for trial in 0..50u64 {
        let dim = 1 + rng.gen_range(64) as usize;
        let k = 1 + rng.gen_range(dim as u64) as usize;
        let mut idx: Vec<u32> =
            rng.sample_indices(dim, k).into_iter().map(|i| i as u32).collect();
        idx.sort_unstable();
        let plan = IndexPlan::sparse(idx, dim);
        for bits in [16u32, 32, 64] {
            let dense: Vec<u64> = (0..dim).map(|_| rng.next_u64()).collect();
            let packed = plan.encode(&dense, bits);
            assert_eq!(packed.len(), k, "trial={trial}");
            let scattered = plan.scatter(&packed);
            let mut projected: Vec<u64> =
                dense.iter().map(|&w| w & ccesa::util::mod_mask(bits)).collect();
            plan.project(&mut projected);
            assert_eq!(scattered, projected, "trial={trial} bits={bits}");
        }
    }
}
