//! Golden-vector suite for the hand-rolled crypto, through the public API.
//!
//! Unit tests inside each module already pin most primitives; this file is
//! the integration-level contract: the exact byte-for-byte RFC/NIST vectors
//! a re-implementation (or a perf rewrite of a hot path) must keep passing,
//! with no access to crate internals.
//!
//! Sources: RFC 8439 (ChaCha20, Poly1305, AEAD), RFC 5869 (HKDF-SHA256),
//! RFC 4231 (HMAC-SHA256), RFC 7748 (X25519), FIPS 180-4 (SHA-256).

use ccesa::crypto::chacha20::ChaCha20;
use ccesa::crypto::hkdf;
use ccesa::crypto::hmac::hmac_sha256;
use ccesa::crypto::poly1305::poly1305;
use ccesa::crypto::sha256::{sha256, Sha256};
use ccesa::crypto::x25519::{public_key, x25519, BASEPOINT};
use ccesa::crypto::{aead, dh};
use ccesa::util::hex;

// ---------------------------------------------------------------- ChaCha20

/// RFC 8439 §2.4.2: keystream encryption with counter = 1.
#[test]
fn chacha20_rfc8439_encryption() {
    let key = hex::decode_array::<32>(
        "000102030405060708090a0b0c0d0e0f101112131415161718191a1b1c1d1e1f",
    )
    .unwrap();
    let nonce = hex::decode_array::<12>("000000000000004a00000000").unwrap();
    let mut data = b"Ladies and Gentlemen of the class of '99: If I could offer you \
only one tip for the future, sunscreen would be it."
        .to_vec();
    ChaCha20::new(&key, &nonce).apply_keystream(1, &mut data);
    assert_eq!(
        hex::encode(&data),
        "6e2e359a2568f98041ba0728dd0d6981e97e7aec1d4360c20a27afccfd9fae0b\
         f91b65c5524733ab8f593dabcd62b3571639d624e65152ab8f530c359f0861d8\
         07ca0dbf500d6a6156a38e088a22b65e52bc514d16ccf806818ce91ab7793736\
         5af90bbf74a35be6b40b8eedf2785e42874d"
    );
}

/// RFC 8439 §2.3.2: the raw block function.
#[test]
fn chacha20_rfc8439_block() {
    let key = hex::decode_array::<32>(
        "000102030405060708090a0b0c0d0e0f101112131415161718191a1b1c1d1e1f",
    )
    .unwrap();
    let nonce = hex::decode_array::<12>("000000090000004a00000000").unwrap();
    let mut block = [0u8; 64];
    ChaCha20::new(&key, &nonce).block(1, &mut block);
    assert_eq!(
        hex::encode(&block),
        "10f1e7e4d13b5915500fdd1fa32071c4c7d1f4c733c068030422aa9ac3d46c4e\
         d2826446079faa0914c2d705d98b02a2b5129cd1de164eb9cbd083e8a2503c4e"
    );
}

// ---------------------------------------------------------------- Poly1305

/// RFC 8439 §2.5.2.
#[test]
fn poly1305_rfc8439() {
    let key = hex::decode_array::<32>(
        "85d6be7857556d337f4452fe42d506a80103808afb0db2fd4abff6af4149f51b",
    )
    .unwrap();
    let tag = poly1305(&key, b"Cryptographic Forum Research Group");
    assert_eq!(hex::encode(&tag), "a8061dc1305136c6c22b8baf0c0127a9");
}

// ---------------------------------------------------------------- AEAD

/// RFC 8439 §2.8.2: ChaCha20-Poly1305 seal, and open on the golden output.
#[test]
fn aead_rfc8439_seal_and_open() {
    let key = hex::decode_array::<32>(
        "808182838485868788898a8b8c8d8e8f909192939495969798999a9b9c9d9e9f",
    )
    .unwrap();
    let nonce = hex::decode_array::<12>("070000004041424344454647").unwrap();
    let aad = hex::decode("50515253c0c1c2c3c4c5c6c7").unwrap();
    let pt = b"Ladies and Gentlemen of the class of '99: If I could offer you \
only one tip for the future, sunscreen would be it.";
    let sealed = aead::seal(&key, &nonce, &aad, pt);
    assert_eq!(
        hex::encode(&sealed),
        "d31a8d34648e60db7b86afbc53ef7ec2a4aded51296e08fea9e2b5a736ee62d6\
         3dbea45e8ca9671282fafb69da92728b1a71de0a9e060b2905d6a5b67ecd3b36\
         92ddbd7f2d778b8c9803aee328091b58fab324e4fad675945585808b4831d7bc\
         3ff4def08e4b7a9de576d26586cec64b6116\
         1ae10b594f09e26a7e902ecbd0600691"
    );
    assert_eq!(aead::open(&key, &nonce, &aad, &sealed).unwrap(), pt.to_vec());
    // a flipped tag bit must fail authentication
    let mut bad = sealed;
    let last = bad.len() - 1;
    bad[last] ^= 1;
    assert!(aead::open(&key, &nonce, &aad, &bad).is_err());
}

// ---------------------------------------------------------------- SHA-256

/// FIPS 180-4 examples plus the empty string.
#[test]
fn sha256_fips_vectors() {
    for (msg, digest) in [
        (
            &b""[..],
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855",
        ),
        (
            &b"abc"[..],
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad",
        ),
        (
            &b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"[..],
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1",
        ),
    ] {
        assert_eq!(hex::encode(&sha256(msg)), digest);
    }
}

/// The one-million-'a' FIPS vector, streamed incrementally.
#[test]
fn sha256_million_a_streaming() {
    let mut h = Sha256::new();
    for _ in 0..20_000 {
        h.update(&[b'a'; 50]);
    }
    assert_eq!(
        hex::encode(&h.finalize()),
        "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
    );
}

// ---------------------------------------------------------------- HMAC

/// RFC 4231 test cases 1, 2 and 6.
#[test]
fn hmac_sha256_rfc4231() {
    let out = hmac_sha256(&[0x0b; 20], b"Hi There");
    assert_eq!(
        hex::encode(&out),
        "b0344c61d8db38535ca8afceaf0bf12b881dc200c9833da726e9376c2e32cff7"
    );
    let out = hmac_sha256(b"Jefe", b"what do ya want for nothing?");
    assert_eq!(
        hex::encode(&out),
        "5bdcc146bf60754e6a042426089575c75a003f089d2739839dec58b964ec3843"
    );
    let out = hmac_sha256(
        &[0xaa; 131],
        b"Test Using Larger Than Block-Size Key - Hash Key First",
    );
    assert_eq!(
        hex::encode(&out),
        "60e431591ee0b67f0d8a26aacbf5b77f8e0bc6213728c5140546040f0ee37f54"
    );
}

// ---------------------------------------------------------------- HKDF

/// RFC 5869 Test Case 1 (basic).
#[test]
fn hkdf_rfc5869_case1() {
    let ikm = [0x0b; 22];
    let salt = hex::decode("000102030405060708090a0b0c").unwrap();
    let info = hex::decode("f0f1f2f3f4f5f6f7f8f9").unwrap();
    let prk = hkdf::extract(&salt, &ikm);
    assert_eq!(
        hex::encode(&prk),
        "077709362c2e32df0ddc3f0dc47bba6390b6c73bb50f9c3122ec844ad7c2b3e5"
    );
    let mut okm = [0u8; 42];
    hkdf::expand(&prk, &info, &mut okm);
    assert_eq!(
        hex::encode(&okm),
        "3cb25f25faacd57a90434f64d0362f2a2d2d0a90cf1a5a4c5db02d56ecc4c5bf34007208d5b887185865"
    );
}

/// RFC 5869 Test Case 2 (longer inputs, multi-block expand).
#[test]
fn hkdf_rfc5869_case2() {
    let ikm: Vec<u8> = (0x00..=0x4f).collect();
    let salt: Vec<u8> = (0x60..=0xaf).collect();
    let info: Vec<u8> = (0xb0..=0xff).collect();
    let prk = hkdf::extract(&salt, &ikm);
    assert_eq!(
        hex::encode(&prk),
        "06a6b88c5853361a06104c9ceb35b45cef760014904671014a193f40c15fc244"
    );
    let mut okm = [0u8; 82];
    hkdf::expand(&prk, &info, &mut okm);
    assert_eq!(
        hex::encode(&okm),
        "b11e398dc80327a1c8e7f78c596a49344f012eda2d4efad8a050cc4c19afa97c\
         59045a99cac7827271cb41c65e590e09da3275600c2f09b8367793a9aca3db71\
         cc30c58179ec3e87c14c01d5c1f3434f1d87"
    );
}

/// RFC 5869 Test Case 3 (zero-length salt and info).
#[test]
fn hkdf_rfc5869_case3() {
    let ikm = [0x0b; 22];
    let prk = hkdf::extract(&[], &ikm);
    let mut okm = [0u8; 42];
    hkdf::expand(&prk, &[], &mut okm);
    assert_eq!(
        hex::encode(&okm),
        "8da4e775a563c18f715f802a063c5a31b8a11f5c5ee1879ec3454e5f3c738d2d9d201395faa4b61a96c8"
    );
}

// ---------------------------------------------------------------- X25519

/// RFC 7748 §5.2 scalar-multiplication vectors.
#[test]
fn x25519_rfc7748_scalarmult() {
    let k = hex::decode_array::<32>(
        "a546e36bf0527c9d3b16154b82465edd62144c0ac1fc5a18506a2244ba449ac4",
    )
    .unwrap();
    let u = hex::decode_array::<32>(
        "e6db6867583030db3594c1a424b15f7c726624ec26b3353b10a903a6d0ab1c4c",
    )
    .unwrap();
    assert_eq!(
        hex::encode(&x25519(&k, &u)),
        "c3da55379de9c6908e94ea4df28d084f32eccf03491c71f754b4075577a28552"
    );
    let k = hex::decode_array::<32>(
        "4b66e9d4d1b4673c5ad22691957d6af5c11b6421e0ea01d42ca4169e7918ba0d",
    )
    .unwrap();
    let u = hex::decode_array::<32>(
        "e5210f12786811d3f4b7959d0538ae2c31dbe7106fc03c3efc4cd549c715a493",
    )
    .unwrap();
    assert_eq!(
        hex::encode(&x25519(&k, &u)),
        "95cbde9476e8907d7aade45cb4b873f88b595a68799fa152e6f8f7647aac7957"
    );
}

/// RFC 7748 §6.1 Diffie-Hellman: Alice and Bob derive the same secret.
#[test]
fn x25519_rfc7748_dh() {
    let alice_sk = hex::decode_array::<32>(
        "77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a",
    )
    .unwrap();
    let bob_sk = hex::decode_array::<32>(
        "5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb",
    )
    .unwrap();
    let bob_pk = public_key(&bob_sk);
    assert_eq!(
        hex::encode(&bob_pk),
        "de9edb7d7b7dc1b4d35b61c2ece435373f8343c85b78674dadfc7e146f882b4f"
    );
    let alice_pk = public_key(&alice_sk);
    let shared = x25519(&alice_sk, &bob_pk);
    assert_eq!(shared, x25519(&bob_sk, &alice_pk));
    assert_eq!(
        hex::encode(&shared),
        "4a5d9d5ba4ce2de1728e3bf480350f25e07e21c947d19e3376f09b3c1e161742"
    );
    assert_eq!(hex::encode(&x25519(&alice_sk, &BASEPOINT)), hex::encode(&alice_pk));
}

// -------------------------------------------------- protocol KDF contract

/// The protocol's key-agreement outputs are pinned down to domain
/// separation: same DH point, different info strings, different keys — and
/// both equal HKDF("ccesa/v1", point, info) computed through the public
/// HKDF API.
#[test]
fn dh_kdf_domain_separation_contract() {
    let alice_sk = hex::decode_array::<32>(
        "77076d0a7318a57d3c16c17251b26645df4c2f87ebc0992ab177fba51db92c2a",
    )
    .unwrap();
    let bob_sk = hex::decode_array::<32>(
        "5dab087e624a8a4b79e17f8b83800ee66f3bb1292618b6fd1c2f8b27ff88e0eb",
    )
    .unwrap();
    let bob_pk = public_key(&bob_sk);
    let point = dh::shared_point(&alice_sk, &bob_pk);
    let mask = dh::agree_mask_seed(&alice_sk, &bob_pk);
    let enc = dh::agree_enc_key(&alice_sk, &bob_pk);
    assert_ne!(mask, enc);
    assert_eq!(mask, hkdf::hkdf32(b"ccesa/v1", &point, b"mask-seed"));
    assert_eq!(enc, hkdf::hkdf32(b"ccesa/v1", &point, b"enc-key"));
    // symmetric for the peer
    assert_eq!(mask, dh::agree_mask_seed(&bob_sk, &public_key(&alice_sk)));
}
