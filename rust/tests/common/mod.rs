//! Shared helpers for the integration suites.

use ccesa::protocol::{ProtocolConfig, Topology};

/// The common (n, t, dim, topology, seed) configuration shape — one
/// definition instead of a builder chain per test file. Panics on invalid
/// parameters; production code goes through `ProtocolConfig::builder`.
pub fn base(n: usize, t: usize, dim: usize, topology: Topology, seed: u64) -> ProtocolConfig {
    ProtocolConfig::builder()
        .clients(n)
        .threshold(t)
        .model_dim(dim)
        .topology(topology)
        .seed(seed)
        .build()
        .expect("test config must be valid")
}
