//! Parallel-vs-serial bit-identity of the mask pipeline.
//!
//! The multi-core unmasking path (`par::for_each_slice` +
//! `prg::apply_mask_range`) claims exact equality with the serial pass for
//! every partition, offset, thread count, vector length (including the
//! 256-word x16-batch boundary and the remainder tail) and mask width.
//! These tests are that claim.

use ccesa::crypto::prg::{
    apply_mask, apply_mask_range, expand_masks, expand_masks_at, NONCE_PAIRWISE, NONCE_SELF,
};
use ccesa::par;
use ccesa::util::mod_mask;
use ccesa::util::rng::Rng;

fn base_vector(len: usize, bits: u32, salt: u64) -> Vec<u64> {
    let modm = mod_mask(bits);
    let mut rng = Rng::new(0xB0_0F ^ salt);
    (0..len).map(|_| rng.next_u64() & modm).collect()
}

/// Sweep every length 0..=600 — crossing the 256-word x16-batch boundary
/// at 256 and 512 and exercising the remainder tail everywhere else — and
/// every deterministic partition into 1/2/4/8 shards: composing
/// `apply_mask_range` over the shards must equal the serial `apply_mask`.
#[test]
fn sharded_apply_equals_serial_for_all_lengths_and_threads() {
    let seed = [0xC4u8; 32];
    for bits in [16u32, 32, 64] {
        for len in 0..=600usize {
            let base = base_vector(len, bits, len as u64);
            let mut serial = base.clone();
            apply_mask(&mut serial, &seed, &NONCE_PAIRWISE, bits, len % 2 == 0);
            for threads in [1usize, 2, 4, 8] {
                let mut sharded = base.clone();
                for r in par::partition(len, threads) {
                    apply_mask_range(
                        &mut sharded[r.start..r.end],
                        &seed,
                        &NONCE_PAIRWISE,
                        bits,
                        len % 2 == 0,
                        r.start,
                    );
                }
                assert_eq!(
                    sharded, serial,
                    "bits={bits} len={len} threads={threads}"
                );
            }
        }
    }
}

/// The same equality through real worker threads (`par::for_each_slice`),
/// at lengths that straddle the batch boundary and the tail.
#[test]
fn threaded_apply_equals_serial() {
    let seed = [0x77u8; 32];
    for bits in [16u32, 32, 48, 64] {
        for len in [0usize, 1, 255, 256, 257, 511, 513, 600, 4096, 5000] {
            let base = base_vector(len, bits, 0x7E ^ len as u64);
            let mut serial = base.clone();
            apply_mask(&mut serial, &seed, &NONCE_SELF, bits, false);
            for threads in [1usize, 2, 4, 8] {
                let mut acc = base.clone();
                par::for_each_slice(&mut acc, threads, |offset, slice| {
                    apply_mask_range(slice, &seed, &NONCE_SELF, bits, false, offset);
                });
                assert_eq!(acc, serial, "bits={bits} len={len} threads={threads}");
            }
        }
    }
}

/// Arbitrary (start, len) windows — not just partition boundaries — match
/// the same slice of the full serial expansion, for both keystream layouts
/// (one word per element at b ≤ 32, two at b > 32).
#[test]
fn arbitrary_shard_offsets_match_serial_expansion() {
    let seed = [0x0Du8; 32];
    let mut rng = Rng::new(0x0FF5E7);
    for bits in [16u32, 32, 48, 64] {
        let total = 1500usize;
        let mut full = vec![0u64; total];
        expand_masks(&seed, &NONCE_PAIRWISE, bits, &mut full);
        for _ in 0..40 {
            let start = rng.gen_range(total as u64) as usize;
            let len = rng.gen_range((total - start) as u64 + 1) as usize;
            let mut window = vec![0u64; len];
            expand_masks_at(&seed, &NONCE_PAIRWISE, bits, start, &mut window);
            assert_eq!(
                &window[..],
                &full[start..start + len],
                "bits={bits} start={start} len={len}"
            );

            // and the fused form: applying the window range onto a base
            // equals adding the full expansion's slice manually
            let modm = mod_mask(bits);
            let base = base_vector(len, bits, (start * 31 + len) as u64);
            let mut fused = base.clone();
            apply_mask_range(&mut fused, &seed, &NONCE_PAIRWISE, bits, true, start);
            let manual: Vec<u64> = base
                .iter()
                .zip(&full[start..start + len])
                .map(|(b, m)| b.wrapping_sub(*m) & modm)
                .collect();
            assert_eq!(fused, manual, "bits={bits} start={start} len={len}");
        }
    }
}

/// A mask applied sharded and removed serially (or vice versa) cancels
/// exactly — the round-trip the server/client pair performs every round.
#[test]
fn sharded_apply_serial_remove_round_trip() {
    let seed = [0xEEu8; 32];
    for bits in [16u32, 32, 64] {
        let len = 777usize;
        let base = base_vector(len, bits, 0xE0);
        let mut acc = base.clone();
        par::for_each_slice(&mut acc, 4, |offset, slice| {
            apply_mask_range(slice, &seed, &NONCE_SELF, bits, false, offset);
        });
        assert_ne!(acc, base, "mask must change the vector");
        apply_mask(&mut acc, &seed, &NONCE_SELF, bits, true);
        assert_eq!(acc, base, "bits={bits}");
    }
}
